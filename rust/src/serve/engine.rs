//! The `Engine` session API: a persistent continuous-batching server over
//! registry-leased replicas, with streaming, sampling, cancellation,
//! bounded-queue backpressure, and KV-budgeted admission over a paged
//! [`BlockPool`].
//!
//! Lifecycle:
//!   * [`Engine::start`] spawns `workers` decode threads against a named
//!     model in a [`ModelRegistry`](super::ModelRegistry).  Workers acquire
//!     a [`Lease`](super::Lease) per generation at admission time, so a
//!     [`hot_swap`](super::ModelRegistry::hot_swap) is actually picked up:
//!     new admissions decode on the new generation while in-flight requests
//!     drain on the old lease (the lease drop *is* the drain barrier).
//!   * [`Engine::submit`] enforces a bounded admission queue; when it is
//!     full the caller gets [`SubmitError::QueueFull`] back immediately
//!     instead of unbounded buffering — backpressure, not memory growth.
//!   * KV memory is metered: with a pool configured
//!     ([`EngineOptions::kv`], the default), `submit` reserves the
//!     request's worst-case block count up front.  A dry pool returns
//!     [`SubmitError::KvExhausted`] — the KV sibling of `QueueFull` —
//!     and, if the request outranks an in-flight one
//!     ([`GenRequest::priority`]), flags the lowest-priority victim for
//!     preemption: its blocks are freed and it re-queues for deterministic
//!     recompute (greedy resume re-feeds prompt + emitted tokens, so the
//!     final stream is identical to an uninterrupted run).
//!   * Prompts with a previously-served block-aligned prefix attach the
//!     frozen KV blocks and skip the covered prefill compute; shared
//!     blocks are tagged by model generation so a hot-swap never leaks
//!     stale KV.
//!   * Each accepted request returns a [`Ticket`]: a streaming event
//!     channel ([`Event::Prefilled`] / [`Event::Token`] / [`Event::Done`])
//!     plus [`Ticket::cancel`], observed between decode slices.
//!
//! Scheduling: the worker loop runs one **fused batch step** per round —
//! it gathers the active set's next tokens (one decode row per decoding
//! request, one [`EngineOptions::prefill_chunk`]-row prompt chunk per
//! prefilling request), runs a single batched forward in which every
//! packed weight column is read once for the whole batch
//! ([`PackedModel::decode_step_batch`]), then fans logits/errors back out
//! to the tickets. A long prompt still never stalls the batch (chunks
//! interleave with decode rows), the active set never exceeds
//! `max_batch`, and greedy output stays bit-exact with the unbatched
//! [`PackedModel::generate`]. [`ServeMetrics::batch_occupancy_percentiles`]
//! reports rows per fused step.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::infer::{BatchKv, BlockTiming, KvCache, PackedModel, Scratch, SeqStep, TimingMode};
use crate::kvcache::{Admitted, BlockPool, KvError, KvPoolOptions, KvPoolStats, PagedSeq, PrefixTag};
use crate::obs::trace::{SpanKind, TraceBuilder, TraceShared};
use crate::obs::{self, Histogram};
use crate::util::rng::Rng;

use super::spec::{self, SpecParams};
use super::{Lease, ModelRegistry};

/// Per-request sampling policy. The default is greedy argmax, which
/// reproduces [`PackedModel::generate`] bit-exactly.
#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0.0` means greedy argmax.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits; `0` means the full
    /// vocabulary. Ignored under greedy.
    pub top_k: usize,
    /// Seed for the per-request [`Rng`] — outputs are deterministic per
    /// (prompt, params, seed) regardless of batching or worker count.
    pub seed: u64,
    /// Emitting any of these tokens ends the generation early (the stop
    /// token itself is included in the output).
    pub stop_tokens: Vec<u32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0, stop_tokens: Vec::new() }
    }
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams::default()
    }
}

/// A generation request submitted to an [`Engine`].
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    /// Token budget; `0` completes immediately at admission with empty
    /// output (it never reaches the decode loop, so no underflow).
    pub n_new: usize,
    pub sampling: SamplingParams,
    /// Scheduling priority (higher wins). When the KV pool runs dry, a
    /// submission may preempt an in-flight request of *strictly lower*
    /// priority; equal-priority requests never preempt each other.
    pub priority: i32,
    /// Speculative decoding: draft-propose `k` tokens per round, verify
    /// them against the target in one fused batch step. `None` (the
    /// default) decodes one token per round. Greedy output is identical
    /// either way — speculation only changes throughput.
    pub spec: Option<SpecParams>,
    /// How long this request's shared KV prefix stays worth keeping after
    /// prefill. An expired deadline moves the entry to the front of the
    /// pool's shed order (evicted or spilled before any live entry) —
    /// useful for one-shot prompts that would otherwise squat in the
    /// share map on recency alone. `None` (the default) sheds purely by
    /// usage-weighted LRU.
    pub kv_deadline: Option<Duration>,
    /// End-to-end deadline, measured from submission. An expired request
    /// is shed from the admission queue before it ever decodes, and an
    /// in-flight one finishes with [`FinishReason::DeadlineExceeded`] at
    /// the next scheduling slice — slots and KV blocks free either way.
    /// `None` (the default) never expires.
    pub deadline: Option<Duration>,
}

impl GenRequest {
    /// Greedy request — today's default serving behavior.
    pub fn greedy(prompt: Vec<u32>, n_new: usize) -> GenRequest {
        GenRequest {
            prompt,
            n_new,
            sampling: SamplingParams::greedy(),
            priority: 0,
            spec: None,
            kv_deadline: None,
            deadline: None,
        }
    }

    pub fn sampled(prompt: Vec<u32>, n_new: usize, sampling: SamplingParams) -> GenRequest {
        GenRequest {
            prompt,
            n_new,
            sampling,
            priority: 0,
            spec: None,
            kv_deadline: None,
            deadline: None,
        }
    }

    pub fn with_priority(mut self, priority: i32) -> GenRequest {
        self.priority = priority;
        self
    }

    /// Decode speculatively against the registered draft model `draft`,
    /// proposing up to `k` tokens per verify round.
    pub fn with_spec(mut self, draft: impl Into<String>, k: usize) -> GenRequest {
        self.spec = Some(SpecParams::new(draft, k));
        self
    }

    /// Cap how long this prompt's shared KV prefix outlives the request
    /// (see [`GenRequest::kv_deadline`]).
    pub fn with_kv_deadline(mut self, ttl: Duration) -> GenRequest {
        self.kv_deadline = Some(ttl);
        self
    }

    /// Give the request an end-to-end deadline measured from submission
    /// (see [`GenRequest::deadline`]).
    pub fn with_deadline(mut self, deadline: Duration) -> GenRequest {
        self.deadline = Some(deadline);
        self
    }
}

/// Why a generation ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted the full `n_new` budget.
    Length,
    /// Hit one of `stop_tokens`.
    Stop,
    /// [`Ticket::cancel`] (or engine teardown) ended it early.
    Cancelled,
    /// A KV-cache error ended it (the request fails, the worker survives).
    Failed,
    /// The decode worker serving it panicked; the supervisor drained its
    /// KV back to the pool and respawned the worker on a fresh lease.
    /// Partial tokens may have streamed — resubmitting is safe.
    WorkerFault,
    /// Its end-to-end deadline ([`GenRequest::with_deadline`]) expired —
    /// shed from the queue before decoding, or stopped at a scheduling
    /// slice in flight (partial tokens may have streamed).
    DeadlineExceeded,
}

/// Final accounting for one request, delivered in [`Event::Done`].
#[derive(Debug, Clone)]
pub struct GenStats {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Registry generation of the replica that served the request.
    pub generation: u64,
    /// Submission → admission into the active set.
    pub queue_wait: Duration,
    /// Submission → first emitted token (None if cancelled before one).
    pub ttft: Option<Duration>,
    /// Admission → completion.
    pub service_time: Duration,
}

/// Streaming events delivered on a [`Ticket`], in order:
/// `Prefilled`, then zero or more `Token`s, then exactly one `Done`.
#[derive(Debug, Clone)]
pub enum Event {
    /// The whole prompt has been fed through the model.
    Prefilled { prompt_len: usize },
    /// One decoded token, as soon as it exists.
    Token(u32),
    /// Terminal event; no further events follow.
    Done(GenStats),
}

/// Why a speculative request's draft model cannot be used — a typed
/// submit-time rejection, never a worker panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DraftError {
    /// No model registered under the requested draft name.
    UnknownModel(String),
    /// The draft's vocabulary differs from the target's; verify logits
    /// would index the wrong rows. (Depth and width are free to differ —
    /// drafts page KV from their own per-geometry pool.)
    VocabMismatch { draft: usize, target: usize },
}

impl std::fmt::Display for DraftError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DraftError::UnknownModel(name) => {
                write!(f, "no draft model registered under {name:?}")
            }
            DraftError::VocabMismatch { draft, target } => {
                write!(f, "draft vocab {draft} incompatible with target vocab {target}")
            }
        }
    }
}

/// Suggested client back-off attached to transient backpressure
/// rejections, derived from the engine's recent mean service time and the
/// occupancy of the resource that rejected the request (admission-queue
/// depth or KV pool utilization). It is guidance, not a guarantee:
/// retrying sooner only burns submit attempts, because slots and blocks
/// cannot free faster than in-flight work completes. Consumed by the HTTP
/// front end (`Retry-After` on 429/503) and the load generator's
/// client-side retry loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryAfter(pub Duration);

/// Why [`Engine::submit`] rejected a request. The request rides back in
/// the error so backpressured callers can retry without cloning.
#[derive(Debug, Clone)]
pub enum SubmitError {
    /// The bounded admission queue is full — retry after the suggested
    /// back-off (backpressure).
    QueueFull(GenRequest, RetryAfter),
    /// The KV block pool cannot cover the request's worst case — retry as
    /// in-flight requests finish and free blocks (backpressure). If the
    /// request outranked an in-flight one, a preemption has been flagged
    /// and a retry will find the blocks freed.
    KvExhausted(GenRequest, RetryAfter),
    /// The request's worst-case KV need exceeds the entire pool — no
    /// amount of draining (or retrying) can ever admit it. Shrink the
    /// prompt/budget or grow the pool (`--kv-blocks`).
    KvTooLarge(GenRequest),
    /// The requested draft model is missing or vocab-incompatible with
    /// the target — terminal for this request as submitted (drop the
    /// [`GenRequest::spec`] or register a compatible draft).
    DraftRejected(GenRequest, DraftError),
    /// The engine is shutting down; no new work is accepted.
    ShuttingDown(GenRequest),
}

impl SubmitError {
    /// Transient backpressure ([`SubmitError::QueueFull`] /
    /// [`SubmitError::KvExhausted`]): a retry can succeed once in-flight
    /// work drains. The other variants are terminal for this request.
    pub fn is_backpressure(&self) -> bool {
        matches!(self, SubmitError::QueueFull(..) | SubmitError::KvExhausted(..))
    }

    /// Suggested wait before retrying — `Some` only on the transient
    /// backpressure variants.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            SubmitError::QueueFull(_, ra) | SubmitError::KvExhausted(_, ra) => Some(ra.0),
            _ => None,
        }
    }

    /// Take the request back out of the error for a retry.
    pub fn into_request(self) -> GenRequest {
        match self {
            SubmitError::QueueFull(r, _)
            | SubmitError::KvExhausted(r, _)
            | SubmitError::KvTooLarge(r)
            | SubmitError::DraftRejected(r, _)
            | SubmitError::ShuttingDown(r) => r,
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(_, ra) => {
                write!(f, "admission queue full (retry in ~{} ms)", ra.0.as_millis())
            }
            SubmitError::KvExhausted(_, ra) => {
                write!(f, "KV block pool exhausted (retry in ~{} ms)", ra.0.as_millis())
            }
            SubmitError::KvTooLarge(_) => {
                write!(f, "request exceeds the whole KV block pool")
            }
            SubmitError::DraftRejected(_, e) => write!(f, "speculative draft rejected: {e}"),
            SubmitError::ShuttingDown(_) => write!(f, "engine shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Client handle on one submitted request: a streaming event receiver plus
/// cooperative cancellation.
pub struct Ticket {
    pub id: u64,
    events: Receiver<Event>,
    cancelled: Arc<AtomicBool>,
}

impl Ticket {
    /// Request cancellation; the worker observes it between decode slices
    /// and finishes the request with [`FinishReason::Cancelled`].
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Blocking receive of the next event; `None` once the stream ends.
    pub fn recv(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Bounded-wait receive; lets a streaming front end interleave event
    /// delivery with client-liveness probes (disconnect detection).
    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<Event, std::sync::mpsc::RecvTimeoutError> {
        self.events.recv_timeout(timeout)
    }

    /// Drain the stream to completion and return the final stats.
    pub fn wait(self) -> GenStats {
        let mut streamed = Vec::new();
        loop {
            match self.events.recv() {
                Ok(Event::Done(stats)) => return stats,
                Ok(Event::Token(t)) => streamed.push(t),
                Ok(Event::Prefilled { .. }) => {}
                // Worker died without a Done (engine torn down mid-flight):
                // surface what streamed as a cancelled result.
                Err(_) => {
                    return GenStats {
                        id: self.id,
                        tokens: streamed,
                        finish: FinishReason::Cancelled,
                        generation: 0,
                        queue_wait: Duration::ZERO,
                        ttft: None,
                        service_time: Duration::ZERO,
                    }
                }
            }
        }
    }
}

/// Latency summary (milliseconds) over recorded per-request samples.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub n: usize,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    /// `{n, p50, p95, p99}` — the wire form used by `/v1/metrics` and the
    /// load generator's SLO report.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj};
        obj(vec![
            ("n", num(self.n as f64)),
            ("p50", num(self.p50)),
            ("p95", num(self.p95)),
            ("p99", num(self.p99)),
        ])
    }

    /// Compute from a raw sample set (also used by the load generator's
    /// client-side latency series). Nearest-rank: the q-th percentile is
    /// the smallest sample with at least q% of the set at or below it,
    /// i.e. sorted index `ceil(q·n/100) − 1`.
    pub fn of(samples: &[f64]) -> Percentiles {
        if samples.is_empty() {
            return Percentiles::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let at = |q: usize| {
            let rank = (q * s.len()).div_ceil(100);
            s[rank.max(1) - 1]
        };
        Percentiles { n: s.len(), p50: at(50), p95: at(95), p99: at(99) }
    }

    /// The same three percentiles read from a lock-free histogram
    /// (within [`obs::hist::REL_ERROR`] of the exact nearest-rank value).
    pub fn of_histogram(h: &Histogram) -> Percentiles {
        Percentiles {
            n: h.count() as usize,
            p50: h.quantile(50),
            p95: h.quantile(95),
            p99: h.quantile(99),
        }
    }
}

/// Aggregate serving metrics, shared by all workers of one engine.
pub struct ServeMetrics {
    pub completed: AtomicUsize,
    pub cancelled: AtomicUsize,
    /// Requests ended by a KV-cache error (the worker survives).
    pub failed: AtomicUsize,
    /// Requests preempted: KV blocks freed, re-queued for recompute.
    pub preempted: AtomicUsize,
    /// Requests ended by a decode-worker panic (supervisor drained them).
    pub worker_faults: AtomicUsize,
    /// Requests shed or stopped because their end-to-end deadline passed.
    pub deadline_exceeded: AtomicUsize,
    /// Decode workers respawned after a caught panic.
    pub worker_respawns: AtomicUsize,
    pub tokens_out: AtomicUsize,
    /// Peak concurrent active requests observed (batcher invariant probe).
    pub peak_active: AtomicUsize,
    /// Fused batch steps executed (one per replica slot per round).
    pub batch_steps: AtomicUsize,
    /// Total rows (decode tokens + prefill-chunk tokens + verify-run
    /// tokens) over batch steps.
    pub batch_rows: AtomicUsize,
    /// Total sequences over batch steps.
    pub batch_seqs: AtomicUsize,
    /// Requests that ran at least one speculative round.
    pub spec_requests: AtomicUsize,
    /// Draft-model fused decode steps executed.
    pub draft_steps: AtomicUsize,
    /// Speculative verify runs executed (one per spec request per round).
    pub verify_steps: AtomicUsize,
    /// Draft tokens proposed across verify runs.
    pub draft_tokens: AtomicUsize,
    /// Proposed draft tokens the target accepted.
    pub accepted_tokens: AtomicUsize,
    /// Tokens emitted out of verify runs (accepted + correction/bonus).
    pub spec_tokens: AtomicUsize,
    /// Speculative requests degraded to plain decode (draft removed,
    /// vocab-incompatible after a hot-swap, or draft KV exhausted).
    pub spec_degraded: AtomicUsize,
    /// Service time (admission → completion) of finished requests, kept as
    /// a running mean (µs sum + count) for retry-after derivation.
    service_us: AtomicU64,
    service_n: AtomicUsize,
    /// Submission → admission latency, in ms.
    queue_wait_ms: Histogram,
    /// Submission → first token, in ms.
    ttft_ms: Histogram,
    /// Per-request mean inter-token latency (time from first to last
    /// token over tokens−1), recorded for requests that emitted ≥ 2.
    tpot_ms: Histogram,
    /// Rows per fused batch step.
    batch_occ: Histogram,
    /// Engine start — the `uptime_ms` anchor.
    started: Instant,
    started_unix_ms: u64,
    /// Named counters/gauges registered by the rest of the stack (e.g.
    /// per-phase decode timers); exported by both metrics endpoints.
    obs: obs::Registry,
    /// Per-request span recording, when `EngineOptions::trace` is set.
    trace: Option<Arc<TraceShared>>,
    /// The workers' KV pool (None on the legacy contiguous path).
    pool: Option<Arc<BlockPool>>,
    /// Draft-model KV pools, created lazily per draft geometry
    /// (layers × width) — a draft never shares the target's page tables.
    draft_pools: Mutex<HashMap<(usize, usize), Arc<BlockPool>>>,
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        ServeMetrics {
            completed: AtomicUsize::new(0),
            cancelled: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            preempted: AtomicUsize::new(0),
            worker_faults: AtomicUsize::new(0),
            deadline_exceeded: AtomicUsize::new(0),
            worker_respawns: AtomicUsize::new(0),
            tokens_out: AtomicUsize::new(0),
            peak_active: AtomicUsize::new(0),
            batch_steps: AtomicUsize::new(0),
            batch_rows: AtomicUsize::new(0),
            batch_seqs: AtomicUsize::new(0),
            spec_requests: AtomicUsize::new(0),
            draft_steps: AtomicUsize::new(0),
            verify_steps: AtomicUsize::new(0),
            draft_tokens: AtomicUsize::new(0),
            accepted_tokens: AtomicUsize::new(0),
            spec_tokens: AtomicUsize::new(0),
            spec_degraded: AtomicUsize::new(0),
            service_us: AtomicU64::new(0),
            service_n: AtomicUsize::new(0),
            queue_wait_ms: Histogram::new(),
            ttft_ms: Histogram::new(),
            tpot_ms: Histogram::new(),
            batch_occ: Histogram::new(),
            started: Instant::now(),
            started_unix_ms: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            obs: obs::Registry::new(),
            trace: None,
            pool: None,
            draft_pools: Mutex::new(HashMap::new()),
        }
    }
}

impl ServeMetrics {
    fn record_latency(&self, queue_wait: Duration, ttft: Option<Duration>, tpot: Option<f64>) {
        self.queue_wait_ms.record(queue_wait.as_secs_f64() * 1e3);
        if let Some(t) = ttft {
            self.ttft_ms.record(t.as_secs_f64() * 1e3);
        }
        if let Some(t) = tpot {
            self.tpot_ms.record(t);
        }
    }

    fn record_service(&self, service: Duration) {
        self.service_us.fetch_add(service.as_micros() as u64, Ordering::Relaxed);
        self.service_n.fetch_add(1, Ordering::Relaxed);
    }

    /// Mean service time (admission → completion) over finished requests;
    /// `None` before any finished. The retry-after hints scale off this.
    pub fn mean_service(&self) -> Option<Duration> {
        let n = self.service_n.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        Some(Duration::from_micros(self.service_us.load(Ordering::Relaxed) / n as u64))
    }

    /// One fused batch step of `seqs` sequences covering `rows` rows.
    fn record_batch(&self, seqs: usize, rows: usize) {
        self.batch_steps.fetch_add(1, Ordering::Relaxed);
        self.batch_rows.fetch_add(rows, Ordering::Relaxed);
        self.batch_seqs.fetch_add(seqs, Ordering::Relaxed);
        self.batch_occ.record(rows as f64);
    }

    /// p50/p95/p99 of rows per fused batch step (decode batch occupancy —
    /// how much weight-read amortization the scheduler is achieving).
    pub fn batch_occupancy_percentiles(&self) -> Percentiles {
        Percentiles::of_histogram(&self.batch_occ)
    }

    /// Mean rows per fused batch step over the engine's lifetime.
    pub fn mean_batch_rows(&self) -> f64 {
        let steps = self.batch_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_rows.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Mean sequences per fused batch step (rows minus this is the share
    /// contributed by multi-row prefill chunks).
    pub fn mean_batch_seqs(&self) -> f64 {
        let steps = self.batch_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_seqs.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// p50/p95/p99 of submission → admission, in ms.
    pub fn queue_wait_percentiles(&self) -> Percentiles {
        Percentiles::of_histogram(&self.queue_wait_ms)
    }

    /// p50/p95/p99 of submission → first token, in ms.
    pub fn ttft_percentiles(&self) -> Percentiles {
        Percentiles::of_histogram(&self.ttft_ms)
    }

    /// p50/p95/p99 of per-request mean inter-token latency (TPOT), in ms
    /// (requests that emitted ≥ 2 tokens). With TTFT this is the SLO pair
    /// the load generator scores against.
    pub fn tpot_percentiles(&self) -> Percentiles {
        Percentiles::of_histogram(&self.tpot_ms)
    }

    /// Time since the engine's metrics were created (engine start).
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Engine start as Unix milliseconds.
    pub fn started_unix_ms(&self) -> u64 {
        self.started_unix_ms
    }

    /// The engine's named-metric registry. Resolve counter/gauge handles
    /// once at setup; recording through them is lock-free.
    pub fn obs(&self) -> &obs::Registry {
        &self.obs
    }

    /// The trace recorder, when tracing is enabled.
    pub fn trace(&self) -> Option<&Arc<TraceShared>> {
        self.trace.as_ref()
    }

    /// KV pool utilization, shared-block hit rate, CoW/eviction counters —
    /// `None` when the engine runs without a pool.
    pub fn kv(&self) -> Option<KvPoolStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// Stats of every draft-model KV pool (one per draft geometry that
    /// has served a speculative request).
    pub fn draft_kv(&self) -> Vec<KvPoolStats> {
        lock_recover(&self.draft_pools).values().map(|p| p.stats()).collect()
    }

    /// The per-geometry draft pool, created on first use.
    pub(crate) fn draft_pool(
        &self,
        n_layers: usize,
        d: usize,
        opts: KvPoolOptions,
    ) -> Arc<BlockPool> {
        lock_recover(&self.draft_pools)
            .entry((n_layers, d))
            .or_insert_with(|| {
                let p = Arc::new(BlockPool::new(opts, n_layers, d));
                if let Some(tr) = &self.trace {
                    p.set_obs(tr.clone());
                }
                p
            })
            .clone()
    }

    /// Draft-token acceptance rate across verify runs (0 before any ran).
    pub fn acceptance_rate(&self) -> f64 {
        let proposed = self.draft_tokens.load(Ordering::Relaxed);
        if proposed == 0 {
            return 0.0;
        }
        self.accepted_tokens.load(Ordering::Relaxed) as f64 / proposed as f64
    }

    /// Mean accepted draft tokens per verify step.
    pub fn accepted_per_verify(&self) -> f64 {
        let steps = self.verify_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.accepted_tokens.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Mean tokens emitted per verify step (accepted + the free
    /// correction/bonus token — a plain decode step emits exactly 1).
    pub fn spec_tokens_per_verify(&self) -> f64 {
        let steps = self.verify_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.spec_tokens.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Full snapshot as JSON — the `GET /v1/metrics` payload and the load
    /// generator's server-side reconciliation source.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj, Json};
        let c = |a: &AtomicUsize| num(a.load(Ordering::Relaxed) as f64);
        let mut pairs = vec![
            ("uptime_ms", num(self.uptime().as_secs_f64() * 1e3)),
            ("started_unix_ms", num(self.started_unix_ms as f64)),
            ("completed", c(&self.completed)),
            ("cancelled", c(&self.cancelled)),
            ("failed", c(&self.failed)),
            ("preempted", c(&self.preempted)),
            ("worker_faults", c(&self.worker_faults)),
            ("deadline_exceeded", c(&self.deadline_exceeded)),
            ("worker_respawns", c(&self.worker_respawns)),
            ("tokens_out", c(&self.tokens_out)),
            ("peak_active", c(&self.peak_active)),
            ("batch_steps", c(&self.batch_steps)),
            ("mean_batch_rows", num(self.mean_batch_rows())),
            ("mean_batch_seqs", num(self.mean_batch_seqs())),
            ("mean_service_ms", match self.mean_service() {
                Some(d) => num(d.as_secs_f64() * 1e3),
                None => Json::Null,
            }),
            ("queue_wait_ms", self.queue_wait_percentiles().to_json()),
            ("ttft_ms", self.ttft_percentiles().to_json()),
            ("tpot_ms", self.tpot_percentiles().to_json()),
            ("batch_occupancy_rows", self.batch_occupancy_percentiles().to_json()),
            (
                "spec",
                obj(vec![
                    ("requests", c(&self.spec_requests)),
                    ("draft_steps", c(&self.draft_steps)),
                    ("verify_steps", c(&self.verify_steps)),
                    ("draft_tokens", c(&self.draft_tokens)),
                    ("accepted_tokens", c(&self.accepted_tokens)),
                    ("degraded", c(&self.spec_degraded)),
                    ("acceptance_rate", num(self.acceptance_rate())),
                    ("tokens_per_verify", num(self.spec_tokens_per_verify())),
                ]),
            ),
        ];
        if let Some(kv) = self.kv() {
            pairs.push(("kv", kv_stats_json(&kv)));
        }
        let snap = self.obs.snapshot();
        if !snap.is_empty() {
            let entries: Vec<(String, Json)> =
                snap.into_iter().map(|(k, v)| (k, num(v))).collect();
            pairs.push((
                "obs",
                Json::Obj(entries.into_iter().collect()),
            ));
        }
        if let Some(tr) = &self.trace {
            pairs.push((
                "trace",
                obj(vec![
                    ("completed", num(tr.completed_count() as f64)),
                    ("dropped", num(tr.dropped_traces() as f64)),
                ]),
            ));
        }
        obj(pairs)
    }

    /// Add every serving metric to a Prometheus exposition under the
    /// given `model` label — counters, gauges, latency summaries, KV pool
    /// stats (target and draft pools distinguished by a `pool` label),
    /// and everything registered on [`ServeMetrics::obs`].
    pub fn render_prometheus(&self, ex: &mut obs::prom::Exposition, model: &str) {
        let l: &[(&str, &str)] = &[("model", model)];
        let c = |a: &AtomicUsize| a.load(Ordering::Relaxed) as f64;
        ex.counter("requests_completed_total", "requests finished normally", l, c(&self.completed));
        ex.counter("requests_cancelled_total", "requests cancelled", l, c(&self.cancelled));
        ex.counter("requests_failed_total", "requests ended by a KV error", l, c(&self.failed));
        ex.counter("requests_preempted_total", "priority preemptions", l, c(&self.preempted));
        ex.counter(
            "requests_worker_fault_total",
            "requests ended by a decode-worker panic",
            l,
            c(&self.worker_faults),
        );
        ex.counter(
            "requests_deadline_exceeded_total",
            "requests shed or stopped past their end-to-end deadline",
            l,
            c(&self.deadline_exceeded),
        );
        ex.counter(
            "worker_respawns_total",
            "decode workers respawned after a caught panic",
            l,
            c(&self.worker_respawns),
        );
        ex.counter("tokens_out_total", "tokens emitted", l, c(&self.tokens_out));
        ex.gauge("peak_active_requests", "peak concurrent active requests", l, c(&self.peak_active));
        ex.counter("batch_steps_total", "fused batch steps", l, c(&self.batch_steps));
        ex.counter("batch_rows_total", "rows over fused batch steps", l, c(&self.batch_rows));
        ex.counter("batch_seqs_total", "sequences over fused batch steps", l, c(&self.batch_seqs));
        ex.counter("spec_requests_total", "requests that ran a spec round", l, c(&self.spec_requests));
        ex.counter("spec_draft_steps_total", "draft fused decode steps", l, c(&self.draft_steps));
        ex.counter("spec_verify_steps_total", "speculative verify runs", l, c(&self.verify_steps));
        ex.counter("spec_draft_tokens_total", "draft tokens proposed", l, c(&self.draft_tokens));
        ex.counter(
            "spec_accepted_tokens_total",
            "draft tokens accepted by the target",
            l,
            c(&self.accepted_tokens),
        );
        ex.counter("spec_degraded_total", "spec requests degraded to plain decode", l, c(&self.spec_degraded));
        ex.gauge("spec_acceptance_rate", "draft-token acceptance rate", l, self.acceptance_rate());
        ex.gauge("uptime_seconds", "engine uptime", l, self.uptime().as_secs_f64());
        if let Some(d) = self.mean_service() {
            ex.gauge("mean_service_ms", "mean admission-to-completion time", l, d.as_secs_f64() * 1e3);
        }
        summary_of(ex, "queue_wait_ms", "submission to admission latency", l, &self.queue_wait_ms);
        summary_of(ex, "ttft_ms", "submission to first token", l, &self.ttft_ms);
        summary_of(ex, "tpot_ms", "per-request mean inter-token latency", l, &self.tpot_ms);
        summary_of(ex, "batch_occupancy_rows", "rows per fused batch step", l, &self.batch_occ);
        if let Some(kv) = self.kv() {
            kv_stats_prometheus(ex, &kv, &[("model", model), ("pool", "target")]);
        }
        for kv in self.draft_kv() {
            kv_stats_prometheus(ex, &kv, &[("model", model), ("pool", "draft")]);
        }
        if let Some(tr) = &self.trace {
            ex.gauge("trace_completed", "completed traces held in the ring", l, tr.completed_count() as f64);
            ex.counter("trace_dropped_total", "completed traces evicted from the ring", l, tr.dropped_traces() as f64);
        }
        self.obs.render_into(ex, l);
    }
}

/// A histogram as a Prometheus summary (p50/p95/p99 + `_sum`/`_count`).
fn summary_of(
    ex: &mut obs::prom::Exposition,
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    h: &Histogram,
) {
    ex.summary(
        name,
        help,
        labels,
        &[("0.5", h.quantile(50)), ("0.95", h.quantile(95)), ("0.99", h.quantile(99))],
        h.sum(),
        h.count() as f64,
    );
}

/// [`KvPoolStats`] into a Prometheus exposition (every counter the JSON
/// endpoint reports, as proper counter/gauge families).
fn kv_stats_prometheus(
    ex: &mut obs::prom::Exposition,
    kv: &KvPoolStats,
    l: &[(&str, &str)],
) {
    ex.gauge("kv_blocks", "pool block budget", l, kv.n_blocks as f64);
    ex.gauge("kv_in_use_blocks", "blocks currently held", l, kv.in_use as f64);
    ex.gauge("kv_utilization", "in-use fraction of the block budget", l, kv.utilization);
    ex.gauge("kv_peak_in_use_blocks", "peak blocks held", l, kv.peak_in_use as f64);
    ex.gauge("kv_capacity_bytes", "pool capacity", l, kv.capacity_bytes as f64);
    ex.gauge("kv_resident_bytes", "resident KV bytes", l, kv.resident_bytes as f64);
    ex.gauge("kv_shared_attached_blocks", "blocks attached from shared prefixes", l, kv.shared_attached as f64);
    ex.gauge("kv_shared_hit_rate", "prompt blocks served from shared prefixes", l, kv.shared_hit_rate);
    ex.gauge("kv_registered_prefixes", "prefixes in the share map", l, kv.registered_prefixes as f64);
    ex.gauge("kv_spilled_entries", "prefix entries in the spill tier", l, kv.spilled_entries as f64);
    ex.gauge("kv_spilled_blocks", "blocks in the spill tier", l, kv.spilled_blocks as f64);
    ex.gauge("kv_spilled_bytes", "bytes in the spill tier", l, kv.spilled_bytes as f64);
    ex.counter("kv_cow_copies_total", "copy-on-write block copies", l, kv.cow_copies as f64);
    ex.counter("kv_evicted_blocks_total", "blocks evicted from the share map", l, kv.evicted_blocks as f64);
    ex.counter("kv_unused_tail_returned_total", "over-reserved tail blocks returned", l, kv.unused_tail_returned as f64);
    ex.counter("kv_spill_writes_total", "prefix entries written to the spill tier", l, kv.spill_writes as f64);
    ex.counter("kv_spill_faults_total", "spilled entries faulted back", l, kv.spill_faults as f64);
    ex.counter("kv_spill_fault_fails_total", "failed fault-backs", l, kv.spill_fault_fails as f64);
}

/// [`KvPoolStats`] as JSON (shared by `/v1/metrics` and the SLO report).
pub fn kv_stats_json(kv: &KvPoolStats) -> crate::util::json::Json {
    use crate::util::json::{num, obj};
    obj(vec![
        ("n_blocks", num(kv.n_blocks as f64)),
        ("block_size", num(kv.block_size as f64)),
        ("mode", crate::util::json::s(kv.mode.name())),
        ("block_bytes", num(kv.block_bytes as f64)),
        ("capacity_bytes", num(kv.capacity_bytes as f64)),
        ("resident_bytes", num(kv.resident_bytes as f64)),
        ("in_use", num(kv.in_use as f64)),
        ("utilization", num(kv.utilization)),
        ("peak_in_use", num(kv.peak_in_use as f64)),
        ("peak_utilization", num(kv.peak_utilization)),
        ("shared_attached", num(kv.shared_attached as f64)),
        ("prompt_blocks", num(kv.prompt_blocks as f64)),
        ("shared_hit_rate", num(kv.shared_hit_rate)),
        ("cow_copies", num(kv.cow_copies as f64)),
        ("evicted_blocks", num(kv.evicted_blocks as f64)),
        ("unused_tail_returned", num(kv.unused_tail_returned as f64)),
        ("registered_prefixes", num(kv.registered_prefixes as f64)),
        ("spilled_entries", num(kv.spilled_entries as f64)),
        ("spilled_blocks", num(kv.spilled_blocks as f64)),
        ("spilled_bytes", num(kv.spilled_bytes as f64)),
        ("spill_writes", num(kv.spill_writes as f64)),
        ("spill_faults", num(kv.spill_faults as f64)),
        ("spill_fault_fails", num(kv.spill_fault_fails as f64)),
    ])
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Registry name the workers serve.
    pub model: String,
    /// Max concurrent requests per worker (prefilling + decoding).
    pub max_batch: usize,
    /// Decode threads; each holds its own replica(s).
    pub workers: usize,
    /// Bounded admission queue depth; beyond it `submit` returns
    /// [`SubmitError::QueueFull`].
    pub queue_depth: usize,
    /// Prompt tokens fed per scheduling slice, so prefill interleaves with
    /// decode instead of stalling the active set.
    pub prefill_chunk: usize,
    /// KV block-pool geometry. `Some` (the default) meters KV memory:
    /// admission reserves blocks, prompts share prefixes, preemption kicks
    /// in under pressure. `None` falls back to per-request contiguous
    /// caches with no budget (the seed behavior).
    pub kv: Option<KvPoolOptions>,
    /// KV geometry for *draft* pools (speculative decoding); pools are
    /// created lazily per draft (layers × width). `None` (the default)
    /// reuses the target pool geometry from [`EngineOptions::kv`]. Only
    /// consulted in pool mode — without a target pool, drafts use
    /// contiguous caches.
    pub draft_kv: Option<KvPoolOptions>,
    /// Directory for the KV cold tier. `Some` enables disk spill on the
    /// target pool: frozen shared prefixes shed under budget pressure are
    /// written there as CRC-checked `.pqm` files and faulted back when
    /// the prompt recurs. `None` (the default) sheds by dropping.
    pub kv_spill_dir: Option<std::path::PathBuf>,
    /// Record per-request span traces (submit → queue → KV → prefill →
    /// batch steps → terminal) plus pool-level KV events, exportable as
    /// Chrome trace-event JSON. Off (the default) costs nothing: the
    /// per-request handle is `None` and every hook is a skipped `if let`.
    pub trace: bool,
    /// Per-component decode phase timing on the workers' replicas;
    /// accumulated deltas fold into [`ServeMetrics::obs`] as
    /// `decode_phase_us_total{phase=..}` counters after every fused step.
    pub timing: TimingMode,
    /// Watchdog budget for one fused round: a worker stuck inside a
    /// single round longer than this reports as stalled and the engine
    /// turns [`HealthState::Degraded`] (detection only — the stuck thread
    /// is not killed, but health-checking callers stop routing to it).
    pub stall_budget: Duration,
    /// How long after a caught worker panic [`Engine::health`] keeps
    /// reporting [`HealthState::Degraded`], so health probes polling at
    /// human cadence still observe the fault before Ready returns.
    pub fault_cooldown: Duration,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            model: "default".into(),
            max_batch: 4,
            workers: 1,
            queue_depth: 64,
            prefill_chunk: 16,
            kv: Some(KvPoolOptions::default()),
            draft_kv: None,
            kv_spill_dir: None,
            trace: false,
            timing: TimingMode::Off,
            stall_budget: Duration::from_secs(5),
            fault_cooldown: Duration::from_millis(300),
        }
    }
}

struct Admission {
    id: u64,
    req: GenRequest,
    enqueued: Instant,
    /// Absolute end-to-end deadline (submit time + requested budget).
    deadline: Option<Instant>,
    events: Sender<Event>,
    cancelled: Arc<AtomicBool>,
    /// KV reservation + shared prefix granted at submit time (pool mode).
    admitted: Option<Admitted>,
    /// Span recorder (tracing enabled only); carries the submit span.
    trace: Option<Box<TraceBuilder>>,
}

/// Entry in the engine-wide in-flight index, used by `submit` to pick a
/// preemption victim without touching worker state.
struct ActiveInfo {
    priority: i32,
    preempt: Arc<AtomicBool>,
}

/// A pending high-priority submission that flagged a preemption: while it
/// stands (and has not expired), workers do not resume lower-priority
/// preempted requests, so the retrying submitter wins the freed blocks.
struct Demand {
    priority: i32,
    expires: Instant,
}

/// A preempted request parked for recompute: everything needed to re-feed
/// prompt + emitted tokens and continue the stream deterministically.
struct Preempted {
    id: u64,
    prompt: Vec<u32>,
    emitted: Vec<u32>,
    n_new: usize,
    sampling: SamplingParams,
    priority: i32,
    /// Speculative config; the draft state itself is rebuilt on resume
    /// (its KV blocks were freed with the target's at preemption).
    spec: Option<SpecParams>,
    /// Whether the request was already counted in
    /// [`ServeMetrics::spec_requests`] — a preempt/resume cycle must not
    /// count it twice.
    spec_counted: bool,
    rng: Rng,
    /// Weight identity the emitted tokens were decoded under; resume on a
    /// different generation would silently splice two models' outputs.
    tag: PrefixTag,
    prefilled_sent: bool,
    enqueued: Instant,
    /// Absolute end-to-end deadline — parking does not pause the clock.
    deadline: Option<Instant>,
    started: Instant,
    first_token: Option<Duration>,
    events: Sender<Event>,
    cancelled: Arc<AtomicBool>,
    /// Span recorder, parked with the request (tracing enabled only).
    trace: Option<Box<TraceBuilder>>,
}

/// State shared between `submit` and the workers (beyond the queue).
#[derive(Default)]
struct EngineShared {
    requeue: Mutex<VecDeque<Preempted>>,
    active: Mutex<HashMap<u64, ActiveInfo>>,
    demand: Mutex<Option<Demand>>,
    /// Admissions sitting in the bounded queue (incremented on a
    /// successful `try_send`, decremented at worker poll) — the queue
    /// depth signal [`Engine::health`] compares against capacity.
    queued: AtomicUsize,
}

/// Lock a shared-state mutex, recovering the data from a poisoned guard.
/// Every mutex routed through here protects a plain map/queue/option
/// whose invariants hold between operations, so state left by a panicking
/// holder is still structurally valid — recover-and-continue keeps the
/// engine serving where propagating the poison would cascade one worker's
/// panic into every sibling thread and `submit` caller (ISSUE 9).
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Coarse serving condition, derived from worker liveness, admission
/// queue depth, and KV pressure — served at `GET /v1/health` (ISSUE 9).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HealthState {
    /// Accepting work; no fault indicator raised.
    Ready,
    /// Still serving, but impaired: a worker recently panicked or is
    /// stuck in a fused round, the admission queue is saturated, or the
    /// KV pool is fully charged. Load balancers should prefer other
    /// replicas; clients should expect backpressure.
    Degraded { reason: String },
    /// Shutting down: in-flight requests drain, new submissions bounce.
    Draining,
}

impl HealthState {
    /// Stable wire name: `ready` / `degraded` / `draining`.
    pub fn name(&self) -> &'static str {
        match self {
            HealthState::Ready => "ready",
            HealthState::Degraded { .. } => "degraded",
            HealthState::Draining => "draining",
        }
    }

    /// Should a health endpoint answer 200 for this state?
    pub fn is_ready(&self) -> bool {
        matches!(self, HealthState::Ready)
    }

    /// The degradation reason, when degraded.
    pub fn reason(&self) -> Option<&str> {
        match self {
            HealthState::Degraded { reason } => Some(reason),
            _ => None,
        }
    }

    /// `{status, reason?}` — the `GET /v1/health` wire form.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, s};
        let mut pairs = vec![("status", s(self.name()))];
        if let Some(r) = self.reason() {
            pairs.push(("reason", s(r)));
        }
        obj(pairs)
    }
}

/// Per-worker liveness shared between the supervisors and
/// [`Engine::health`]. Heartbeats are µs offsets from `epoch`, one atomic
/// per worker (0 = parked between rounds), so the decode hot path pays
/// two relaxed stores per fused round and never a lock.
struct WorkerHealth {
    epoch: Instant,
    /// Per worker: when its current fused round began (µs from `epoch`,
    /// clamped to ≥ 1); 0 while idle between rounds.
    step_started: Vec<AtomicU64>,
    /// Panics caught by the supervisors over the engine's lifetime.
    panics: AtomicUsize,
    /// When the most recent panic was caught — drives the degraded
    /// cool-down window ([`EngineOptions::fault_cooldown`]).
    last_fault: Mutex<Option<Instant>>,
}

impl WorkerHealth {
    fn new(workers: usize) -> WorkerHealth {
        WorkerHealth {
            epoch: Instant::now(),
            step_started: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            panics: AtomicUsize::new(0),
            last_fault: Mutex::new(None),
        }
    }

    fn round_begin(&self, widx: usize) {
        let us = self.epoch.elapsed().as_micros() as u64;
        self.step_started[widx].store(us.max(1), Ordering::Relaxed);
    }

    fn round_end(&self, widx: usize) {
        self.step_started[widx].store(0, Ordering::Relaxed);
    }

    /// A panic unwound mid-round: clear the heartbeat (the round is over,
    /// however badly) and open the fault cool-down window.
    fn note_panic(&self, widx: usize) {
        self.step_started[widx].store(0, Ordering::Relaxed);
        self.panics.fetch_add(1, Ordering::Relaxed);
        *lock_recover(&self.last_fault) = Some(Instant::now());
    }

    /// Index of a worker stuck inside one fused round past `budget`.
    fn stalled_worker(&self, budget: Duration) -> Option<usize> {
        let now = self.epoch.elapsed().as_micros() as u64;
        self.step_started.iter().position(|s| {
            let t0 = s.load(Ordering::Relaxed);
            t0 != 0 && now.saturating_sub(t0) > budget.as_micros() as u64
        })
    }

    /// Was a panic caught within the last `cooldown`?
    fn fault_within(&self, cooldown: Duration) -> bool {
        lock_recover(&self.last_fault).is_some_and(|t| t.elapsed() < cooldown)
    }
}

/// How long a flagged preemption holds resume of lower-priority requests
/// open for the retrying submitter.
const DEMAND_TTL: Duration = Duration::from_millis(250);

/// Persistent serving engine. Dropping (or [`Engine::shutdown`]) closes the
/// admission queue, drains in-flight requests, and joins the workers.
pub struct Engine {
    tx: Option<SyncSender<Admission>>,
    handles: Vec<JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    next_id: AtomicU64,
    registry: Arc<ModelRegistry>,
    model: String,
    pool: Option<Arc<BlockPool>>,
    shared: Arc<EngineShared>,
    /// Admission-queue depth and total batch slots (workers × max_batch),
    /// kept for retry-after derivation.
    queue_depth: usize,
    slots: usize,
    /// Worker liveness (heartbeats, caught panics) for [`Engine::health`].
    health: Arc<WorkerHealth>,
    stall_budget: Duration,
    fault_cooldown: Duration,
}

/// Retry-after clamp bounds and the cold-start fallback (no completed
/// request yet to estimate service time from).
const RETRY_FLOOR: Duration = Duration::from_millis(1);
const RETRY_CEIL: Duration = Duration::from_secs(2);
const RETRY_DEFAULT: Duration = Duration::from_millis(25);

impl Engine {
    /// Spawn the decode workers against `opts.model` in `registry`. Fails
    /// fast if no such model is registered.
    pub fn start(registry: &Arc<ModelRegistry>, opts: EngineOptions) -> Result<Engine> {
        let probe = registry
            .acquire(&opts.model)
            .ok_or_else(|| anyhow!("no model registered under {:?}", opts.model))?;
        let pool = opts
            .kv
            .map(|kv| Arc::new(BlockPool::new(kv, probe.model.cfg.n_layers, probe.model.cfg.d_model)));
        drop(probe);
        if let (Some(p), Some(dir)) = (pool.as_ref(), opts.kv_spill_dir.as_ref()) {
            p.enable_spill(dir)
                .map_err(|e| anyhow!("cannot enable KV spill tier at {}: {e}", dir.display()))?;
        }
        let (tx, rx) = sync_channel(opts.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let trace = opts.trace.then(TraceShared::new);
        if let (Some(p), Some(tr)) = (pool.as_ref(), trace.as_ref()) {
            p.set_obs(tr.clone());
        }
        let metrics =
            Arc::new(ServeMetrics { pool: pool.clone(), trace, ..Default::default() });
        let shared = Arc::new(EngineShared::default());
        let health = Arc::new(WorkerHealth::new(opts.workers.max(1)));
        // Chaos schedules set out-of-process (`PQUANT_FAILPOINTS`) arm
        // once, before any worker can evaluate a site.
        crate::util::failpoint::arm_from_env();
        let handles = (0..opts.workers.max(1))
            .map(|widx| {
                let ctx = WorkerCtx {
                    widx,
                    registry: registry.clone(),
                    rx: rx.clone(),
                    opts: opts.clone(),
                    metrics: metrics.clone(),
                    kv_pool: pool.clone(),
                    shared: shared.clone(),
                    health: health.clone(),
                };
                std::thread::spawn(move || supervise_worker(ctx))
            })
            .collect();
        Ok(Engine {
            tx: Some(tx),
            handles,
            metrics,
            next_id: AtomicU64::new(0),
            registry: registry.clone(),
            model: opts.model,
            pool,
            shared,
            queue_depth: opts.queue_depth.max(1),
            slots: opts.workers.max(1) * opts.max_batch.max(1),
            health,
            stall_budget: opts.stall_budget,
            fault_cooldown: opts.fault_cooldown,
        })
    }

    /// Back-off for a full admission queue: the backlog drains in roughly
    /// `queue_depth / slots` service times.
    fn queue_retry_after(&self) -> RetryAfter {
        let mean = self.metrics.mean_service().unwrap_or(RETRY_DEFAULT);
        let rounds = ((self.queue_depth + self.slots - 1) / self.slots).max(1) as u32;
        RetryAfter((mean * rounds).clamp(RETRY_FLOOR, RETRY_CEIL))
    }

    /// Back-off for a dry KV pool: scaled by pool occupancy — a pool that
    /// is mostly map-held (low live utilization) frees on the next evict,
    /// a fully live pool frees only as requests complete.
    fn kv_retry_after(&self) -> RetryAfter {
        let mean = self.metrics.mean_service().unwrap_or(RETRY_DEFAULT);
        let util = self.pool.as_ref().map_or(1.0, |p| p.stats().utilization).max(0.25);
        RetryAfter(mean.mul_f64(util).clamp(RETRY_FLOOR, RETRY_CEIL))
    }

    /// Submit a request. Zero-budget requests complete immediately with
    /// empty output; otherwise the request reserves its KV worst case
    /// against the pool ([`SubmitError::KvExhausted`] is the block-budget
    /// sibling of [`SubmitError::QueueFull`]) and enters the bounded
    /// queue.
    pub fn submit(&self, req: GenRequest) -> std::result::Result<Ticket, SubmitError> {
        let mut req = req;
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::ShuttingDown(req));
        };
        // Speculative requests validate their draft at submit time: a
        // missing or vocab-incompatible draft is a typed rejection here,
        // never a worker panic. (`k == 0` proposes nothing — normalize to
        // plain decode.)
        if req.spec.as_ref().is_some_and(|s| s.k == 0) {
            req.spec = None;
        }
        if let Some(sp) = req.spec.as_ref() {
            let Some(draft) = self.registry.acquire(&sp.draft) else {
                let e = DraftError::UnknownModel(sp.draft.clone());
                return Err(SubmitError::DraftRejected(req, e));
            };
            if let Some(target) = self.registry.acquire(&self.model) {
                let (dv, tv) = (draft.model.cfg.vocab, target.model.cfg.vocab);
                if dv != tv {
                    let e = DraftError::VocabMismatch { draft: dv, target: tv };
                    return Err(SubmitError::DraftRejected(req, e));
                }
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (etx, erx) = channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let ticket = Ticket { id, events: erx, cancelled: cancelled.clone() };
        let mut trace = self.metrics.trace().map(|tr| {
            let mut b = tr.begin(id);
            // Anchored at begin_us so the later Queue span (which starts
            // there too) keeps per-request timestamps monotone.
            let t0 = b.begin_us();
            b.span_since(SpanKind::Submit, t0, req.prompt.len() as u64, req.n_new as u64);
            b
        });
        if req.n_new == 0 {
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            if let Some(tr) = trace.take() {
                tr.finish(reason_code(FinishReason::Length), 0);
            }
            let _ = etx.send(Event::Done(GenStats {
                id,
                tokens: Vec::new(),
                finish: FinishReason::Length,
                generation: 0,
                queue_wait: Duration::ZERO,
                ttft: None,
                service_time: Duration::ZERO,
            }));
            return Ok(ticket);
        }
        let admitted = match self.pool.as_ref() {
            None => None,
            Some(kvp) => {
                let total = kv_worst_case(req.prompt.len(), req.n_new);
                // A worst case no drain can ever cover must fail fast, not
                // spin retry loops (and, preempted mid-flight, it could
                // never re-admit once its shared prefix was evicted).
                if kvp.blocks_for(total) > kvp.n_blocks() {
                    return Err(SubmitError::KvTooLarge(req));
                }
                match kvp.admit(&req.prompt, total, self.current_tag()) {
                    Ok(a) => {
                        self.clear_demand_if_covered(req.priority);
                        Some(a)
                    }
                    Err(KvError::OutOfBlocks { .. } | KvError::CacheOverflow { .. }) => {
                        self.flag_preemption(req.priority);
                        return Err(SubmitError::KvExhausted(req, self.kv_retry_after()));
                    }
                }
            }
        };
        let enqueued = Instant::now();
        let adm = Admission {
            id,
            deadline: req.deadline.map(|d| enqueued + d),
            req,
            enqueued,
            events: etx,
            cancelled,
            admitted,
            trace,
        };
        match tx.try_send(adm) {
            // A dropped rejection releases its KV reservation on the way out.
            Ok(()) => {
                self.shared.queued.fetch_add(1, Ordering::Relaxed);
                Ok(ticket)
            }
            Err(TrySendError::Full(adm)) => {
                Err(SubmitError::QueueFull(adm.req, self.queue_retry_after()))
            }
            Err(TrySendError::Disconnected(adm)) => Err(SubmitError::ShuttingDown(adm.req)),
        }
    }

    /// [`Engine::submit`], blocking on backpressure: retries while the
    /// admission queue or the KV pool is full (both drain as in-flight
    /// requests finish) and returns any terminal error as-is.
    pub fn submit_blocking(&self, req: GenRequest) -> std::result::Result<Ticket, SubmitError> {
        let mut req = req;
        loop {
            match self.submit(req) {
                Ok(t) => return Ok(t),
                Err(e) if e.is_backpressure() => {
                    // Honor the engine's own guidance, capped so a caller
                    // polling a nearly-drained queue is not oversleeping.
                    let wait = e
                        .retry_after()
                        .unwrap_or(Duration::from_millis(1))
                        .min(Duration::from_millis(20));
                    req = e.into_request();
                    std::thread::sleep(wait);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Identity of the weights currently serving `self.model` — the share
    /// tag new KV will be keyed under.
    fn current_tag(&self) -> PrefixTag {
        match self.registry.acquire(&self.model) {
            Some(lease) => PrefixTag(lease.uid as usize, lease.generation),
            None => PrefixTag::default(),
        }
    }

    /// Flag the lowest-priority in-flight request strictly below
    /// `priority` for preemption, and post a demand so workers hold its
    /// resume until the retrying submitter claims the freed blocks.
    fn flag_preemption(&self, priority: i32) {
        let flagged = {
            let act = lock_recover(&self.shared.active);
            // One victim at a time: while a flagged preemption is still in
            // flight (its blocks not yet freed), a 1ms-retry loop must not
            // cascade through the whole active set flagging more.
            if act.values().any(|i| i.preempt.load(Ordering::Relaxed)) {
                true
            } else {
                let victim = act
                    .iter()
                    .filter(|(_, i)| i.priority < priority)
                    .min_by_key(|(id, i)| (i.priority, std::cmp::Reverse(**id)));
                match victim {
                    Some((_, info)) => {
                        info.preempt.store(true, Ordering::Relaxed);
                        true
                    }
                    None => false,
                }
            }
        };
        if flagged {
            let mut d = lock_recover(&self.shared.demand);
            // Never downgrade a live demand: a lower-priority waiter must
            // not open the resume gate a higher-priority one closed.
            let floor = d
                .as_ref()
                .filter(|dd| Instant::now() < dd.expires)
                .map_or(i32::MIN, |dd| dd.priority);
            *d = Some(Demand {
                priority: priority.max(floor),
                expires: Instant::now() + DEMAND_TTL,
            });
        }
    }

    fn clear_demand_if_covered(&self, priority: i32) {
        let mut d = lock_recover(&self.shared.demand);
        if d.as_ref().is_some_and(|dd| priority >= dd.priority) {
            *d = None;
        }
    }

    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// The engine's KV pool, when admission is block-budgeted.
    pub fn kv_pool(&self) -> Option<&Arc<BlockPool>> {
        self.pool.as_ref()
    }

    /// Coarse serving condition, recomputed per call from live signals
    /// (ISSUE 9). Checks run in severity order — draining trumps
    /// everything, then worker faults, then saturation — and the first
    /// raised indicator names the state. `Degraded` still serves; only
    /// [`HealthState::Ready`] maps to HTTP 200 at `GET /v1/health`.
    pub fn health(&self) -> HealthState {
        if self.tx.is_none() {
            return HealthState::Draining;
        }
        if self.health.fault_within(self.fault_cooldown) {
            let n = self.health.panics.load(Ordering::Relaxed);
            return HealthState::Degraded {
                reason: format!("worker panic caught (lifetime total {n}); respawn warming up"),
            };
        }
        if let Some(w) = self.health.stalled_worker(self.stall_budget) {
            return HealthState::Degraded {
                reason: format!(
                    "worker {w} stuck in one fused round past the {:?} stall budget",
                    self.stall_budget
                ),
            };
        }
        if self.shared.queued.load(Ordering::Relaxed) >= self.queue_depth {
            return HealthState::Degraded { reason: "admission queue saturated".to_string() };
        }
        if let Some(st) = self.pool.as_ref().map(|p| p.stats()) {
            if st.in_use >= st.n_blocks {
                return HealthState::Degraded { reason: "kv pool fully charged".to_string() };
            }
        }
        HealthState::Ready
    }

    /// Stop accepting work, drain in-flight requests, join the workers.
    pub fn shutdown(mut self) -> Arc<ServeMetrics> {
        self.close();
        self.metrics.clone()
    }

    fn close(&mut self) {
        self.tx.take(); // disconnect: workers drain their active sets, then exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.close();
    }
}

// ------------------------------------------------------------------ worker

/// One leased replica a worker decodes on. Dropping the slot drops the
/// lease — that is what the registry's hot-swap drain barrier counts.
struct ReplicaSlot {
    lease: Lease,
    model: PackedModel,
    inflight: usize,
    /// Cumulative per-phase timing already folded into the registry
    /// counters (the model's summary minus this is the next delta).
    folded: BlockTiming,
}

impl ReplicaSlot {
    /// Weight identity this slot decodes with (the prefix-share tag).
    /// Built on the entry's process-unique `uid`, not its address — a
    /// recycled allocation must never revive another model's KV.
    fn tag(&self) -> PrefixTag {
        PrefixTag(self.lease.uid as usize, self.lease.generation)
    }
}

/// Worker-local replica pool. Requests pin the slot (generation) they were
/// admitted on; new admissions track the registry's current generation.
struct ReplicaPool {
    registry: Arc<ModelRegistry>,
    name: String,
    slots: Vec<Option<ReplicaSlot>>,
    newest: Option<usize>,
    /// Applied to every replica this pool clones.
    timing: TimingMode,
}

impl ReplicaPool {
    /// Slot serving the registry's *current* generation, cloning a fresh
    /// replica if a hot-swap moved past everything we hold. Returns `None`
    /// only when the model was removed and no replica survives.
    fn current_slot(&mut self) -> Option<usize> {
        match self.registry.acquire(&self.name) {
            Some(lease) => {
                if let Some(n) = self.newest {
                    if let Some(s) = self.slots[n].as_ref() {
                        // Entry identity, not generation number: a
                        // remove+re-register resets the per-name counter,
                        // so equal numbers can name different weights.
                        if Arc::ptr_eq(s.lease.entry(), lease.entry()) {
                            return Some(n); // probe lease drops here
                        }
                    }
                }
                let mut model = lease.replica();
                if self.timing != TimingMode::Off {
                    model.set_timing(self.timing);
                }
                let slot =
                    ReplicaSlot { lease, model, inflight: 0, folded: BlockTiming::default() };
                let idx = match self.slots.iter().position(|s| s.is_none()) {
                    Some(i) => {
                        self.slots[i] = Some(slot);
                        i
                    }
                    None => {
                        self.slots.push(Some(slot));
                        self.slots.len() - 1
                    }
                };
                if let Some(prev) = self.newest {
                    if prev != idx {
                        self.retire_if_idle(prev);
                    }
                }
                self.newest = Some(idx);
                Some(idx)
            }
            // Removed from the registry: keep draining on the newest
            // surviving replica (the lease keeps its weights alive).
            None => self.newest.filter(|&n| self.slots[n].is_some()),
        }
    }

    /// One request on `idx` finished; drop the slot (and its lease) once it
    /// is idle and superseded. The newest slot is kept without probing the
    /// registry — a swap that outran it is caught by the next admission
    /// (`current_slot`) or by idle housekeeping (`drop_idle_stale`), so the
    /// common no-swap completion pays no registry round-trip.
    fn release(&mut self, idx: usize) {
        let Some(s) = self.slots[idx].as_mut() else { return };
        s.inflight -= 1;
        if s.inflight == 0 && Some(idx) != self.newest {
            self.drop_slot(idx);
        }
    }

    /// Idle housekeeping: release leases a hot-swap (or removal) has moved
    /// past, so a drain barrier is not held open by an idle worker.
    fn drop_idle_stale(&mut self) {
        for idx in 0..self.slots.len() {
            let idle = self.slots[idx].as_ref().is_some_and(|s| s.inflight == 0);
            if idle && (Some(idx) != self.newest || self.entry_stale(idx)) {
                self.drop_slot(idx);
            }
        }
    }

    /// Does the registry currently serve a different entry than `idx` holds?
    fn entry_stale(&self, idx: usize) -> bool {
        let held = self.slots[idx].as_ref().unwrap().lease.entry();
        match self.registry.acquire(&self.name) {
            Some(current) => !Arc::ptr_eq(held, current.entry()),
            None => true, // model removed: holding the lease serves nothing
        }
    }

    fn drop_slot(&mut self, idx: usize) {
        self.slots[idx] = None;
        if Some(idx) == self.newest {
            self.newest = None;
        }
    }

    fn retire_if_idle(&mut self, idx: usize) {
        if self.slots[idx].as_ref().is_some_and(|s| s.inflight == 0) {
            self.drop_slot(idx);
        }
    }
}

/// Per-request KV state: paged against the engine pool, or the legacy
/// caller-sized contiguous caches.
enum RequestKv {
    Contig(Vec<KvCache>),
    Paged(PagedSeq),
}

impl RequestKv {
    /// Roll back to `len` positions — the speculative-rejection path.
    /// Paged sequences return whole freed blocks to their allowance.
    fn truncate(&mut self, len: usize) {
        match self {
            RequestKv::Contig(c) => {
                for layer in c.iter_mut() {
                    layer.truncate(len);
                }
            }
            RequestKv::Paged(s) => s.truncate(len),
        }
    }
}

/// Worker-side state of one speculative request: the pinned draft replica
/// slot, the draft's own KV, and the reusable round buffers.
struct SpecState {
    params: SpecParams,
    /// Pinned slot in the worker's per-name draft [`ReplicaPool`]
    /// (`None` until the first speculative round).
    slot: Option<usize>,
    /// Draft KV (paged from the per-geometry draft pool, or contiguous
    /// in pool-less mode). `None` until initialized.
    kv: Option<RequestKv>,
    /// Positions fed into the draft.
    fed: usize,
    /// Draft tokens proposed this round (clamped to the remaining
    /// budget).
    k_eff: usize,
    /// This round's verify run `[pending, d_1..d_k_eff]` (reused).
    run: Vec<u32>,
    /// Draft catch-up staging (reused).
    ctx: Vec<u32>,
    /// Sampled mode: densified proposal rows `q_1..q_k` ([k, vocab]) and
    /// the target-distribution scratch row.
    q_rows: Vec<f32>,
    p_row: Vec<f32>,
    /// Counted once in [`ServeMetrics::spec_requests`].
    counted: bool,
}

impl SpecState {
    fn new(params: SpecParams) -> SpecState {
        SpecState {
            params,
            slot: None,
            kv: None,
            fed: 0,
            k_eff: 0,
            run: Vec::new(),
            ctx: Vec::new(),
            q_rows: Vec::new(),
            p_row: Vec::new(),
            counted: false,
        }
    }
}

/// Release the draft replica slot a departing request pinned (finish,
/// cancel, preemption, failure, degrade — every exit from the active set).
fn release_spec(draft_pools: &mut HashMap<String, ReplicaPool>, spec: &Option<SpecState>) {
    if let Some(sp) = spec {
        if let Some(slot) = sp.slot {
            if let Some(p) = draft_pools.get_mut(&sp.params.draft) {
                p.release(slot);
            }
        }
    }
}

/// What one batch row-set means to its owning request (recorded at
/// step-build time so fan-out never re-derives the plan).
#[derive(Clone, Copy)]
enum RowPlan {
    /// Prompt chunk ending at `end`; `completes` marks the prompt done.
    Prefill { end: usize, completes: bool },
    /// Single sampled-token decode row.
    Decode,
    /// Speculative verify run (`[pending, drafts…]`, logits on every row).
    Spec,
}

/// Worst-case KV positions a request can occupy: every prompt token plus
/// every decoded token except the last sampled one, which is emitted but
/// never fed back through the model.
fn kv_worst_case(prompt_len: usize, n_new: usize) -> usize {
    prompt_len + n_new.saturating_sub(1)
}

/// One in-flight request: its own KV state, RNG, and event stream; pinned
/// to the replica slot it was admitted on.
struct ActiveRequest {
    id: u64,
    /// Original prompt length (`fed[..prompt_len]` is the prompt; a resume
    /// re-feeds emitted tokens after it).
    prompt_len: usize,
    fed: Vec<u32>,
    n_new: usize,
    priority: i32,
    sampling: SamplingParams,
    rng: Rng,
    tokens: Vec<u32>,
    last_logits: Vec<f32>,
    /// Fed tokens processed so far; prefill is done when it reaches
    /// `fed.len()`.
    prefill_pos: usize,
    pos: usize,
    kv: RequestKv,
    /// `tokens.last()` has been emitted but not yet fed to the target
    /// (sampled in phase 1, or left pending by a verify fan-out).
    pending: bool,
    /// Speculative state (None for plain requests, and after a degrade).
    spec: Option<SpecState>,
    /// Prompt prefix registered for sharing (or not applicable).
    registered: bool,
    /// Share-map retention cap carried from [`GenRequest::kv_deadline`];
    /// applied (relative to registration time) when the prefix registers.
    kv_deadline: Option<Duration>,
    prefilled_sent: bool,
    preempt: Arc<AtomicBool>,
    slot: usize,
    generation: u64,
    enqueued: Instant,
    /// Absolute end-to-end deadline, checked once per fused round.
    deadline: Option<Instant>,
    started: Instant,
    first_token: Option<Duration>,
    events: Sender<Event>,
    cancelled: Arc<AtomicBool>,
    /// Span recorder (tracing enabled only).
    trace: Option<Box<TraceBuilder>>,
}

/// Terminal-span reason code for a finish reason (`SpanKind::Terminal`'s
/// `a` payload).
fn reason_code(reason: FinishReason) -> u64 {
    match reason {
        FinishReason::Stop => 0,
        FinishReason::Length => 1,
        FinishReason::Cancelled => 2,
        FinishReason::Failed => 3,
        FinishReason::WorkerFault => 4,
        FinishReason::DeadlineExceeded => 5,
    }
}

fn finish(mut a: ActiveRequest, reason: FinishReason, metrics: &ServeMetrics) {
    let queue_wait = a.started - a.enqueued;
    let service = a.started.elapsed();
    match reason {
        FinishReason::Cancelled => metrics.cancelled.fetch_add(1, Ordering::Relaxed),
        FinishReason::Failed => metrics.failed.fetch_add(1, Ordering::Relaxed),
        FinishReason::WorkerFault => metrics.worker_faults.fetch_add(1, Ordering::Relaxed),
        FinishReason::DeadlineExceeded => {
            metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed)
        }
        _ => metrics.completed.fetch_add(1, Ordering::Relaxed),
    };
    // TPOT: mean inter-token gap from the first to the last emitted token
    // (finish runs right after the last emission, so "now" is the last
    // token's timestamp to within a send).
    let tpot = match a.first_token {
        Some(first) if a.tokens.len() >= 2 => Some(
            a.enqueued.elapsed().saturating_sub(first).as_secs_f64() * 1e3
                / (a.tokens.len() - 1) as f64,
        ),
        _ => None,
    };
    metrics.record_latency(queue_wait, a.first_token, tpot);
    metrics.record_service(service);
    if let Some(tr) = a.trace.take() {
        tr.finish(reason_code(reason), a.tokens.len() as u64);
    }
    let _ = a.events.send(Event::Done(GenStats {
        id: a.id,
        tokens: a.tokens,
        finish: reason,
        generation: a.generation,
        queue_wait,
        ttft: a.first_token,
        service_time: service,
    }));
}

/// End a request that never reached (or could not re-enter) the active
/// set. `Cancelled` for requests the client gave up on (or whose model
/// vanished); `Failed` for engine-side KV/geometry failures the client
/// never asked for.
fn reject_parts_as(
    id: u64,
    enqueued: Instant,
    events: &Sender<Event>,
    metrics: &ServeMetrics,
    trace: Option<Box<TraceBuilder>>,
    finish: FinishReason,
) {
    match finish {
        FinishReason::Failed => metrics.failed.fetch_add(1, Ordering::Relaxed),
        FinishReason::DeadlineExceeded => {
            metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed)
        }
        _ => metrics.cancelled.fetch_add(1, Ordering::Relaxed),
    };
    if let Some(tr) = trace {
        tr.finish(reason_code(finish), 0);
    }
    let _ = events.send(Event::Done(GenStats {
        id,
        tokens: Vec::new(),
        finish,
        generation: 0,
        queue_wait: enqueued.elapsed(),
        ttft: None,
        service_time: Duration::ZERO,
    }));
}

fn reject_parts(
    id: u64,
    enqueued: Instant,
    events: &Sender<Event>,
    metrics: &ServeMetrics,
    trace: Option<Box<TraceBuilder>>,
) {
    reject_parts_as(id, enqueued, events, metrics, trace, FinishReason::Cancelled);
}

fn fail_parts(
    id: u64,
    enqueued: Instant,
    events: &Sender<Event>,
    metrics: &ServeMetrics,
    trace: Option<Box<TraceBuilder>>,
) {
    reject_parts_as(id, enqueued, events, metrics, trace, FinishReason::Failed);
}

/// Finish a preempted request that cannot resume (cancelled while parked,
/// or the serving model changed out from under it).
fn finish_preempted(mut p: Preempted, reason: FinishReason, metrics: &ServeMetrics) {
    match reason {
        FinishReason::Failed => metrics.failed.fetch_add(1, Ordering::Relaxed),
        FinishReason::DeadlineExceeded => {
            metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed)
        }
        _ => metrics.cancelled.fetch_add(1, Ordering::Relaxed),
    };
    let queue_wait = p.started - p.enqueued;
    // No TPOT sample: the parked interval would inflate the gap.
    metrics.record_latency(queue_wait, p.first_token, None);
    if let Some(tr) = p.trace.take() {
        tr.finish(reason_code(reason), p.emitted.len() as u64);
    }
    let _ = p.events.send(Event::Done(GenStats {
        id: p.id,
        tokens: p.emitted,
        finish: reason,
        generation: 0,
        queue_wait,
        ttft: p.first_token,
        service_time: p.started.elapsed(),
    }));
}

/// Registry handles for the six per-component decode-phase counters
/// (`decode_phase_us_total{phase=..}`), resolved once per worker when
/// [`EngineOptions::timing`] is on.
struct PhaseCounters {
    attn_proj: Arc<obs::Counter>,
    attn_core: Arc<obs::Counter>,
    ffn_1bit: Arc<obs::Counter>,
    ffn_8bit: Arc<obs::Counter>,
    router: Arc<obs::Counter>,
    norm_quant: Arc<obs::Counter>,
}

impl PhaseCounters {
    fn new(reg: &obs::Registry) -> PhaseCounters {
        let c = |phase: &str| {
            reg.counter_with(
                "decode_phase_us_total",
                &[("phase", phase)],
                "per-component decode wall time",
            )
        };
        PhaseCounters {
            attn_proj: c("attn_proj"),
            attn_core: c("attn_core"),
            ffn_1bit: c("ffn_1bit"),
            ffn_8bit: c("ffn_8bit"),
            router: c("router"),
            norm_quant: c("norm_quant"),
        }
    }

    /// Fold the delta between the model's cumulative summary `now` and
    /// the already-folded baseline `last` into the counters.
    fn fold(&self, last: &mut BlockTiming, now: BlockTiming) {
        // Delta of the cumulative-µs readings (telescopes exactly: the
        // counter total always equals the model summary in µs).
        let us = |a: Duration, b: Duration| (a.as_micros() as u64).saturating_sub(b.as_micros() as u64);
        self.attn_proj.add(us(now.attn_proj, last.attn_proj));
        self.attn_core.add(us(now.attn_core, last.attn_core));
        self.ffn_1bit.add(us(now.ffn_1bit, last.ffn_1bit));
        self.ffn_8bit.add(us(now.ffn_8bit, last.ffn_8bit));
        self.router.add(us(now.router, last.router));
        self.norm_quant.add(us(now.norm_quant, last.norm_quant));
        *last = now;
    }
}

/// Is resume of a request at `priority` held open for a pending
/// higher-priority demand?
fn demand_blocks(shared: &EngineShared, priority: i32) -> bool {
    let mut d = lock_recover(&shared.demand);
    match d.as_ref() {
        Some(dd) if Instant::now() >= dd.expires => {
            *d = None;
            false
        }
        Some(dd) => priority < dd.priority,
        None => false,
    }
}

/// Everything one decode worker needs, bundled so the supervisor can
/// restart [`worker_loop`] against the same channels after a panic.
struct WorkerCtx {
    widx: usize,
    registry: Arc<ModelRegistry>,
    rx: Arc<Mutex<Receiver<Admission>>>,
    opts: EngineOptions,
    metrics: Arc<ServeMetrics>,
    kv_pool: Option<Arc<BlockPool>>,
    shared: Arc<EngineShared>,
    health: Arc<WorkerHealth>,
}

/// Supervision shell around [`worker_loop`]: one decode worker is one
/// fault domain. A panic anywhere in the fused round unwinds to here; the
/// supervisor fails the stranded in-flight rows with a terminal
/// [`FinishReason::WorkerFault`] event, records the fault (obs counter,
/// trace terminal span, health cool-down), and restarts the loop. The
/// replica pools and scratch live *inside* the unwind boundary, so the
/// respawned loop re-acquires fresh leases from the registry — a panic
/// never strands a hot-swap drain barrier.
fn supervise_worker(ctx: WorkerCtx) {
    let panics = ctx
        .metrics
        .obs()
        .counter("worker_panics_total", "decode worker panics caught by the supervisor");
    // In-flight requests live *outside* the unwind boundary so a panic
    // mid-round leaves them reachable for draining: dropping each one
    // returns its KV blocks (target and draft) to the pools.
    let mut active: Vec<ActiveRequest> = Vec::new();
    loop {
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            worker_loop(&ctx, &mut active);
        }));
        match run {
            // Channel closed and requeue drained: clean exit.
            Ok(()) => return,
            Err(_) => {
                panics.inc();
                ctx.health.note_panic(ctx.widx);
                for a in active.drain(..) {
                    lock_recover(&ctx.shared.active).remove(&a.id);
                    finish(a, FinishReason::WorkerFault, &ctx.metrics);
                }
                ctx.metrics.worker_respawns.fetch_add(1, Ordering::Relaxed);
                // Brief pause before the respawn: a deterministic crash
                // (or a fully-armed failpoint) must not hot-spin the CPU.
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

fn worker_loop(ctx: &WorkerCtx, active: &mut Vec<ActiveRequest>) {
    let WorkerCtx { widx, registry, rx, opts, metrics, kv_pool, shared, health } = ctx;
    let max_batch = opts.max_batch.max(1);
    let prefill_chunk = opts.prefill_chunk.max(1);
    // Draft pools page KV with their own geometry; default to the target
    // pool's knobs when the engine is in pool mode.
    let draft_kv_opts = opts.draft_kv.or(opts.kv);
    let mut pool = ReplicaPool {
        registry: registry.clone(),
        name: opts.model.clone(),
        slots: Vec::new(),
        newest: None,
        timing: opts.timing,
    };
    // Per-phase decode-time counters, resolved once (recording through
    // them is lock-free); `None` when timing is off.
    let phase_counters =
        (opts.timing != TimingMode::Off).then(|| PhaseCounters::new(metrics.obs()));
    // Per draft-model name, a worker-local replica pool — speculative
    // requests pin the draft slot they initialized on, so a draft
    // hot-swap is picked up by *new* speculation while in-flight streams
    // drain losslessly on the old lease.
    let mut draft_pools: HashMap<String, ReplicaPool> = HashMap::new();
    // Per-worker scratch arena: every batch step's intermediates live
    // here, so the steady-state decode loop allocates nothing per token.
    let mut scratch = Scratch::new();
    // Round-bookkeeping buffers, reused across rounds for the same reason
    // (the borrow-holding `steps` list itself is necessarily per-round).
    // Each owner records (active index, row plan) at step-build time, so
    // fan-out never re-derives the chunking/speculation decision.
    let mut slots_in_play: Vec<usize> = Vec::new();
    let mut owners: Vec<(usize, RowPlan)> = Vec::new();
    let mut draft_owners: Vec<usize> = Vec::new();
    let mut spec_groups: Vec<(String, usize)> = Vec::new();
    let mut errs: Vec<Option<KvError>> = Vec::new();
    let mut done: Vec<(usize, FinishReason)> = Vec::new();
    let mut closed = false;
    loop {
        // ---- resume preempted requests into free batch slots ----
        while active.len() < max_batch {
            let Some(kvp) = kv_pool.as_ref() else { break };
            let Some(mut p) = lock_recover(&shared.requeue).pop_front() else { break };
            if p.cancelled.load(Ordering::Relaxed) {
                finish_preempted(p, FinishReason::Cancelled, &metrics);
                continue;
            }
            if p.deadline.is_some_and(|d| Instant::now() >= d) {
                // Parked past its end-to-end budget: the recompute would
                // only produce tokens the client already walked away from.
                finish_preempted(p, FinishReason::DeadlineExceeded, &metrics);
                continue;
            }
            if demand_blocks(shared, p.priority) {
                lock_recover(&shared.requeue).push_front(p);
                break;
            }
            let Some(slot) = pool.current_slot() else {
                // Model gone, nothing to resume on.
                finish_preempted(p, FinishReason::Cancelled, &metrics);
                continue;
            };
            let slot_tag = pool.slots[slot].as_ref().unwrap().tag();
            if slot_tag != p.tag {
                // The model was hot-swapped while this request was parked.
                // Its emitted tokens came from the old weights, so a
                // resume would splice two generations into one stream —
                // fail it instead. (This also covers geometry changes:
                // a different entry always means a different tag.)
                finish_preempted(p, FinishReason::Failed, &metrics);
                continue;
            }
            let mut fed = p.prompt.clone();
            fed.extend_from_slice(&p.emitted);
            // Re-feeding prompt + emitted and finishing the remaining
            // budget needs the same worst case the first admission did.
            let total = kv_worst_case(p.prompt.len(), p.n_new);
            let admitted = match kvp.readmit(&fed, total, slot_tag) {
                Ok(a) => a,
                Err(_) => {
                    // Blocks not free yet; park it and move on.
                    lock_recover(&shared.requeue).push_front(p);
                    break;
                }
            };
            let (generation, vocab) = {
                let s = pool.slots[slot].as_mut().unwrap();
                s.inflight += 1;
                (s.lease.generation, s.model.cfg.vocab)
            };
            let seq = PagedSeq::new(kvp, admitted);
            let prefill_pos = seq.len();
            // Fresh draft state on resume (the old one's KV was freed at
            // preemption), but the spec_requests count carries over.
            let spec_state = p.spec.take().map(|params| {
                let mut s = SpecState::new(params);
                s.counted = p.spec_counted;
                s
            });
            let preempt = Arc::new(AtomicBool::new(false));
            lock_recover(&shared.active)
                .insert(p.id, ActiveInfo { priority: p.priority, preempt: preempt.clone() });
            let mut trace = p.trace.take();
            if let Some(tr) = trace.as_mut() {
                tr.instant(SpanKind::Resume, 0, 0);
                tr.instant(SpanKind::KvReserve, total as u64, prefill_pos as u64);
            }
            active.push(ActiveRequest {
                id: p.id,
                prompt_len: p.prompt.len(),
                fed,
                n_new: p.n_new,
                priority: p.priority,
                sampling: p.sampling,
                rng: p.rng,
                tokens: p.emitted,
                last_logits: vec![0.0; vocab],
                prefill_pos,
                pos: 0,
                kv: RequestKv::Paged(seq),
                pending: false, // resume re-feeds every emitted token
                spec: spec_state,
                registered: true, // resume never re-registers prefixes
                kv_deadline: None,
                prefilled_sent: p.prefilled_sent,
                preempt,
                slot,
                generation,
                enqueued: p.enqueued,
                deadline: p.deadline,
                started: p.started,
                first_token: p.first_token,
                events: p.events,
                cancelled: p.cancelled,
                trace,
            });
            metrics.peak_active.fetch_max(active.len(), Ordering::Relaxed);
        }
        // ---- admission: fill free batch slots from the shared queue ----
        while active.len() < max_batch && !closed {
            // Never hold the queue lock across a blocking wait: an idle
            // worker parked inside the Mutex would stall every sibling's
            // admission check (which runs once per decode slice).
            let polled = {
                let rx = lock_recover(rx);
                match rx.try_recv() {
                    Ok(adm) => {
                        shared.queued.fetch_sub(1, Ordering::Relaxed);
                        Some(adm)
                    }
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        closed = true;
                        None
                    }
                }
            };
            let Some(adm) = polled else { break };
            let Admission { id, req, enqueued, deadline, events, cancelled, admitted, mut trace } =
                adm;
            if cancelled.load(Ordering::Relaxed) {
                reject_parts(id, enqueued, &events, &metrics, trace);
                continue; // `admitted` drops here, releasing the reservation
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                // Expired in the queue: shed before it costs a prefill —
                // the ticket still gets exactly one terminal event, and
                // the dropped reservation frees its blocks on the way out.
                let dl = FinishReason::DeadlineExceeded;
                reject_parts_as(id, enqueued, &events, &metrics, trace, dl);
                continue;
            }
            let Some(slot) = pool.current_slot() else {
                reject_parts(id, enqueued, &events, &metrics, trace); // model gone
                continue;
            };
            let started = Instant::now();
            let (generation, vocab, slot_tag, slot_geometry_ok) = {
                let s = pool.slots[slot].as_mut().unwrap();
                s.inflight += 1;
                let geometry_ok = kv_pool.as_ref().map_or(true, |kvp| {
                    s.model.cfg.n_layers == kvp.n_layers() && s.model.cfg.d_model == kvp.width()
                });
                (s.lease.generation, s.model.cfg.vocab, s.tag(), geometry_ok)
            };
            if !slot_geometry_ok {
                // A hot-swap changed the model's layer count or width out
                // from under the pool: fail the request, don't panic the
                // worker indexing a mis-sized page table.
                pool.release(slot);
                fail_parts(id, enqueued, &events, &metrics, trace);
                continue;
            }
            let kv = match (kv_pool.as_ref(), admitted) {
                (Some(kvp), Some(mut a)) => {
                    if a.tag() != slot_tag {
                        // The serving generation moved between submit and
                        // admission: stale shared KV must not feed the new
                        // weights.
                        if a.discard_sharing().is_err() {
                            pool.release(slot);
                            fail_parts(id, enqueued, &events, &metrics, trace);
                            continue;
                        }
                        a.retag(slot_tag);
                    }
                    RequestKv::Paged(PagedSeq::new(kvp, a))
                }
                // `submit` always admits against the pool before enqueueing;
                // an un-admitted request must not decode unmetered.
                (Some(_), None) => {
                    pool.release(slot);
                    fail_parts(id, enqueued, &events, &metrics, trace);
                    continue;
                }
                (None, _) => {
                    let s = pool.slots[slot].as_mut().unwrap();
                    RequestKv::Contig(s.model.new_caches(kv_worst_case(req.prompt.len(), req.n_new)))
                }
            };
            let prefill_pos = match &kv {
                RequestKv::Paged(seq) => seq.len(), // shared prefix already cached
                RequestKv::Contig(_) => 0,
            };
            if let Some(tr) = trace.as_mut() {
                let t0 = tr.begin_us();
                tr.span_since(SpanKind::Queue, t0, 0, 0);
                let total = kv_worst_case(req.prompt.len(), req.n_new);
                tr.instant(SpanKind::KvReserve, total as u64, prefill_pos as u64);
            }
            let mut prefilled_sent = false;
            if req.prompt.is_empty() {
                let _ = events.send(Event::Prefilled { prompt_len: 0 });
                prefilled_sent = true;
            }
            let preempt = Arc::new(AtomicBool::new(false));
            lock_recover(&shared.active)
                .insert(id, ActiveInfo { priority: req.priority, preempt: preempt.clone() });
            active.push(ActiveRequest {
                id,
                prompt_len: req.prompt.len(),
                rng: Rng::new(req.sampling.seed),
                tokens: Vec::with_capacity(req.n_new),
                last_logits: vec![0.0; vocab],
                prefill_pos,
                pos: 0,
                kv,
                pending: false,
                spec: req.spec.map(SpecState::new),
                registered: false,
                kv_deadline: req.kv_deadline,
                prefilled_sent,
                preempt,
                slot,
                generation,
                enqueued,
                deadline,
                started,
                first_token: None,
                events,
                cancelled,
                fed: req.prompt,
                n_new: req.n_new,
                priority: req.priority,
                sampling: req.sampling,
                trace,
            });
            metrics.peak_active.fetch_max(active.len(), Ordering::Relaxed);
        }
        if active.is_empty() {
            pool.drop_idle_stale();
            for dp in draft_pools.values_mut() {
                dp.drop_idle_stale();
            }
            if closed && lock_recover(&shared.requeue).is_empty() {
                return;
            }
            // Idle backoff outside the queue lock (see admission above).
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }
        // Heartbeat for the stall watchdog: the round is "in flight" from
        // here until the fan-out below completes. Idle parking (above)
        // never looks stuck.
        health.round_begin(*widx);
        if crate::failpoint!("worker.step") {
            panic!("failpoint worker.step: injected decode-worker panic");
        }
        // ---- fused batch round: sweep + sample, then one batched forward
        //      per replica slot, then fan results back out to tickets ----
        //
        // Phase 1: cancellation/preemption sweep and sampling. Every
        // decode-ready request samples its next token from `last_logits`
        // (finishing here if the budget or a stop token says so);
        // survivors contribute one decode row to this round's batch.
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            if active[i].cancelled.load(Ordering::Relaxed) {
                let a = active.swap_remove(i);
                pool.release(a.slot);
                release_spec(&mut draft_pools, &a.spec);
                lock_recover(&shared.active).remove(&a.id);
                // Dropping `a` frees its target KV *and* any draft KV the
                // speculative state held — a cancel mid-verify leaks
                // nothing.
                finish(a, FinishReason::Cancelled, &metrics);
                continue;
            }
            if active[i].deadline.is_some_and(|d| now >= d) {
                let a = active.swap_remove(i);
                pool.release(a.slot);
                release_spec(&mut draft_pools, &a.spec);
                lock_recover(&shared.active).remove(&a.id);
                // Past its end-to-end budget mid-flight: terminal event
                // now, and dropping `a` frees every slot and block it held.
                finish(a, FinishReason::DeadlineExceeded, &metrics);
                continue;
            }
            if active[i].preempt.load(Ordering::Relaxed)
                && matches!(active[i].kv, RequestKv::Paged(_))
            {
                let mut a = active.swap_remove(i);
                pool.release(a.slot);
                release_spec(&mut draft_pools, &a.spec);
                lock_recover(&shared.active).remove(&a.id);
                metrics.preempted.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = a.trace.as_mut() {
                    tr.instant(SpanKind::Preempt, 0, 0);
                }
                let tag = match &a.kv {
                    RequestKv::Paged(seq) => seq.tag(),
                    RequestKv::Contig(_) => PrefixTag::default(),
                };
                let spec_params = a.spec.as_ref().map(|s| s.params.clone());
                let spec_counted = a.spec.as_ref().is_some_and(|s| s.counted);
                lock_recover(&shared.requeue).push_back(Preempted {
                    id: a.id,
                    prompt: a.fed[..a.prompt_len].to_vec(),
                    emitted: a.tokens,
                    n_new: a.n_new,
                    sampling: a.sampling,
                    priority: a.priority,
                    spec: spec_params,
                    spec_counted,
                    rng: a.rng,
                    tag,
                    prefilled_sent: a.prefilled_sent,
                    enqueued: a.enqueued,
                    deadline: a.deadline,
                    started: a.started,
                    first_token: a.first_token,
                    events: a.events,
                    cancelled: a.cancelled,
                    trace: a.trace,
                });
                continue; // a.kv (and any draft KV) drops here — its
                          // blocks return to the pools
            }
            let a = &mut active[i];
            if a.prefill_pos < a.fed.len() {
                i += 1; // prefilling: contributes a prompt chunk below
                continue;
            }
            if a.pending {
                // A speculative verify emitted this token last round; it
                // is still waiting to be fed — nothing to sample.
                i += 1;
                continue;
            }
            let next = sample_token(&a.last_logits, &a.sampling, &mut a.rng);
            a.tokens.push(next);
            a.pending = true;
            if a.first_token.is_none() {
                a.first_token = Some(a.enqueued.elapsed());
            }
            metrics.tokens_out.fetch_add(1, Ordering::Relaxed);
            let _ = a.events.send(Event::Token(next));
            let stopped = a.sampling.stop_tokens.contains(&next);
            if stopped || a.tokens.len() >= a.n_new {
                let a = active.swap_remove(i);
                pool.release(a.slot);
                release_spec(&mut draft_pools, &a.spec);
                lock_recover(&shared.active).remove(&a.id);
                // Dropping the request's PagedSeq returns every block it
                // held — including the reserved-but-unused tail a stop
                // token left behind — to the pool.
                finish(a, if stopped { FinishReason::Stop } else { FinishReason::Length }, &metrics);
            } else {
                i += 1;
            }
        }

        // Phase 1.5: speculative draft proposals. Each spec-configured
        // decode-ready request lazily initializes its draft state (pin a
        // draft replica slot, admit draft KV from the per-geometry pool),
        // then the draft models run one fused step at a time: a catch-up
        // step whose last row yields q_1, then single-row steps for the
        // remaining proposals. Every failure mode degrades the one
        // request to plain decode — never the worker.
        spec_groups.clear();
        for a in active.iter_mut() {
            if a.spec.is_none() || a.prefill_pos < a.fed.len() || !a.pending {
                continue;
            }
            let vocab = a.last_logits.len();
            let ActiveRequest { spec, fed, tokens, pos, n_new, prompt_len, sampling, .. } = a;
            let sp = spec.as_mut().unwrap();
            // The spec.propose failpoint models a draft that dies between
            // rounds: the request degrades to plain decode, like any real
            // draft-side failure.
            let mut degrade = crate::failpoint!("spec.propose");
            if sp.slot.is_none() {
                let dpool =
                    draft_pools.entry(sp.params.draft.clone()).or_insert_with(|| ReplicaPool {
                        registry: registry.clone(),
                        name: sp.params.draft.clone(),
                        slots: Vec::new(),
                        newest: None,
                        // Drafts stay untimed: the Fig 8 phase breakdown
                        // tracks the target model.
                        timing: TimingMode::Off,
                    });
                match dpool.current_slot() {
                    Some(slot) => {
                        // A draft hot-swap may have changed the vocabulary
                        // since submit-time validation; degrade rather
                        // than index the wrong logits rows.
                        let s = dpool.slots[slot].as_mut().unwrap();
                        if s.model.cfg.vocab == vocab {
                            s.inflight += 1;
                            sp.slot = Some(slot);
                        } else {
                            degrade = true;
                        }
                    }
                    None => degrade = true, // draft removed from registry
                }
            }
            if !degrade && sp.kv.is_none() {
                let dpool = draft_pools.get_mut(&sp.params.draft).unwrap();
                let dmodel = &dpool.slots[sp.slot.unwrap()].as_ref().unwrap().model;
                // Worst case the draft ever feeds: the whole context plus
                // one full run of proposals.
                let total = fed.len() + *n_new + sp.params.k;
                match (kv_pool.as_ref(), draft_kv_opts) {
                    (Some(_), Some(kvo)) => {
                        let dp =
                            metrics.draft_pool(dmodel.cfg.n_layers, dmodel.cfg.d_model, kvo);
                        match dp.admit(&[], total, PrefixTag::default()) {
                            Ok(adm) => {
                                sp.kv = Some(RequestKv::Paged(PagedSeq::new(&dp, adm)));
                            }
                            // KvExhausted during draft expansion: the
                            // request keeps decoding plain.
                            Err(KvError::OutOfBlocks { .. })
                            | Err(KvError::CacheOverflow { .. }) => degrade = true,
                        }
                    }
                    _ => sp.kv = Some(RequestKv::Contig(dmodel.new_caches(total))),
                }
                if !degrade {
                    sp.fed = 0;
                    if !sp.counted {
                        sp.counted = true;
                        metrics.spec_requests.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            if degrade {
                release_spec(&mut draft_pools, spec);
                *spec = None;
                metrics.spec_degraded.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // Plan this round's run: catch the draft up through the
            // pending token, propose up to k (clamped so the run never
            // overruns the budget or the target's KV reservation).
            let sp = spec.as_mut().unwrap();
            let remaining = *n_new - tokens.len(); // >= 1, else finished
            sp.k_eff = sp.params.k.min(remaining - 1);
            if sampling.temperature > 0.0 && sp.q_rows.len() < sp.params.k * vocab {
                sp.q_rows.resize(sp.params.k * vocab, 0.0);
            }
            sp.run.clear();
            sp.run.push(*tokens.last().unwrap());
            sp.ctx.clear();
            for i in sp.fed..*pos + 1 {
                sp.ctx.push(if i < fed.len() { fed[i] } else { tokens[i - *prompt_len] });
            }
            if sp.k_eff > 0 {
                let slot = sp.slot.unwrap();
                if !spec_groups.iter().any(|(n, s)| *s == slot && *n == sp.params.draft) {
                    spec_groups.push((sp.params.draft.clone(), slot));
                }
            }
        }
        for gi in 0..spec_groups.len() {
            let (name, slot) = spec_groups[gi].clone();
            let max_k = active
                .iter()
                .filter_map(|a| a.spec.as_ref())
                .filter(|sp| sp.slot == Some(slot) && sp.params.draft == name)
                .map(|sp| sp.k_eff)
                .max()
                .unwrap_or(0);
            for j in 0..max_k {
                draft_owners.clear();
                let mut dsteps: Vec<SeqStep<'_>> = Vec::new();
                for (ai, a) in active.iter_mut().enumerate() {
                    if a.prefill_pos < a.fed.len() || !a.pending {
                        continue;
                    }
                    let Some(sp) = a.spec.as_mut() else { continue };
                    if sp.slot != Some(slot)
                        || sp.params.draft != name
                        || sp.k_eff <= j
                        || sp.kv.is_none()
                    {
                        continue;
                    }
                    let SpecState { ctx, run, kv, fed: sfed, .. } = sp;
                    let toks: &[u32] = if j == 0 { &ctx[..] } else { &run[j..j + 1] };
                    let bkv = match kv.as_mut().unwrap() {
                        RequestKv::Contig(c) => BatchKv::Contig(&mut c[..]),
                        RequestKv::Paged(s) => BatchKv::Paged(s),
                    };
                    draft_owners.push(ai);
                    dsteps.push(SeqStep::new(toks, *sfed, bkv, true));
                }
                if dsteps.is_empty() {
                    break;
                }
                let dmodel = &mut draft_pools.get_mut(&name).unwrap().slots[slot]
                    .as_mut()
                    .unwrap()
                    .model;
                dmodel.decode_step_batch(&mut dsteps, &mut scratch);
                metrics.draft_steps.fetch_add(1, Ordering::Relaxed);
                errs.clear();
                errs.extend(dsteps.iter().map(|s| s.err.clone()));
                drop(dsteps);
                for (si, &ai) in draft_owners.iter().enumerate() {
                    let a = &mut active[ai];
                    if errs[si].is_some() {
                        // Draft KV dried up mid-expansion: this request
                        // degrades to plain decode (its pending token
                        // still feeds through a normal row below) and the
                        // draft's blocks return to their pool right here.
                        release_spec(&mut draft_pools, &a.spec);
                        a.spec = None;
                        metrics.spec_degraded.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    let vocab = a.last_logits.len();
                    let ActiveRequest { spec, sampling, rng, .. } = a;
                    let sp = spec.as_mut().unwrap();
                    sp.fed += if j == 0 { sp.ctx.len() } else { 1 };
                    let next = if sampling.temperature <= 0.0 {
                        argmax(scratch.logits_row(si)) as u32
                    } else {
                        spec::propose_sampled(
                            scratch.logits_row(si),
                            sampling,
                            &mut sp.q_rows[j * vocab..(j + 1) * vocab],
                            rng,
                        )
                    };
                    sp.run.push(next);
                }
            }
        }

        // Phase 2: one fused batch step per replica slot. Prefill chunks
        // and speculative verify runs are rows too — a chunk of M prompt
        // tokens is an M-row GEMM instead of M GEMVs, and a K-token draft
        // run verifies as K+1 rows with per-row logits — so the whole
        // active set advances with each packed weight column read once.
        slots_in_play.clear();
        slots_in_play.extend(active.iter().map(|a| a.slot));
        slots_in_play.sort_unstable();
        slots_in_play.dedup();
        for gi in 0..slots_in_play.len() {
            let slot_id = slots_in_play[gi];
            owners.clear();
            let mut steps: Vec<SeqStep<'_>> = Vec::new();
            for (ai, a) in active.iter_mut().enumerate() {
                if a.slot != slot_id {
                    continue;
                }
                let ActiveRequest { fed, prefill_pos, pos, tokens, kv, spec, .. } = a;
                let bkv = match kv {
                    RequestKv::Contig(c) => BatchKv::Contig(&mut c[..]),
                    RequestKv::Paged(s) => BatchKv::Paged(s),
                };
                if *prefill_pos < fed.len() {
                    let end = (*prefill_pos + prefill_chunk).min(fed.len());
                    owners.push((ai, RowPlan::Prefill { end, completes: end == fed.len() }));
                    steps.push(SeqStep::new(
                        &fed[*prefill_pos..end],
                        *prefill_pos,
                        bkv,
                        end == fed.len(),
                    ));
                } else if let Some(sp) = spec.as_ref() {
                    // Verify run: pending token + proposals, logits on
                    // every row.
                    owners.push((ai, RowPlan::Spec));
                    steps.push(SeqStep::with_all_logits(&sp.run[..], *pos, bkv));
                } else {
                    // Decode row: the token sampled in phase 1 (or left
                    // pending by the last verify fan-out).
                    owners.push((ai, RowPlan::Decode));
                    steps.push(SeqStep::new(&tokens[tokens.len() - 1..], *pos, bkv, true));
                }
            }
            if steps.is_empty() {
                continue;
            }
            let rows: usize = steps.iter().map(|s| s.tokens.len()).sum();
            let n_seqs = steps.len();
            // One clock read per fused step when tracing: every row's span
            // shares the step's start time.
            let step_t0 = metrics.trace().map(|tr| tr.now_us());
            let model = &mut pool.slots[slot_id].as_mut().unwrap().model;
            model.decode_step_batch(&mut steps, &mut scratch);
            metrics.record_batch(n_seqs, rows);
            if let Some(pc) = phase_counters.as_ref() {
                let s = pool.slots[slot_id].as_mut().unwrap();
                pc.fold(&mut s.folded, s.model.timing_summary());
            }
            errs.clear();
            errs.extend(steps.iter().map(|s| s.err.clone()));
            drop(steps);
            // Fan results back out to the tickets, driven by what was
            // recorded at step-build time (never re-derived). Requests
            // that finish here are collected and removed after the loop —
            // `owners` indexes `active`, so no mid-loop swap_remove.
            done.clear();
            for (k, &(ai, plan)) in owners.iter().enumerate() {
                if errs[k].is_some() {
                    done.push((ai, FinishReason::Failed));
                    continue;
                }
                match plan {
                    RowPlan::Prefill { end, completes } => {
                        let a = &mut active[ai];
                        let start = a.prefill_pos;
                        a.prefill_pos = end;
                        if let (Some(tr), Some(t0)) = (a.trace.as_mut(), step_t0) {
                            tr.span_since(SpanKind::PrefillChunk, t0, start as u64, end as u64);
                        }
                        if completes {
                            // This chunk completed the prompt.
                            a.pos = end;
                            if !a.prefilled_sent {
                                a.prefilled_sent = true;
                                let _ =
                                    a.events.send(Event::Prefilled { prompt_len: a.prompt_len });
                            }
                            if !a.registered && a.prompt_len > 0 {
                                a.registered = true;
                                if let (Some(kvp), RequestKv::Paged(seq)) =
                                    (kv_pool.as_ref(), &mut a.kv)
                                {
                                    let deadline = a.kv_deadline.map(|ttl| Instant::now() + ttl);
                                    kvp.register_prefix_deadline(
                                        &a.fed[..a.prompt_len],
                                        seq,
                                        deadline,
                                    );
                                }
                            }
                            a.last_logits.copy_from_slice(scratch.logits_row(k));
                        }
                    }
                    RowPlan::Decode => {
                        let a = &mut active[ai];
                        a.last_logits.copy_from_slice(scratch.logits_row(k));
                        a.pos += 1;
                        a.pending = false;
                        if let (Some(tr), Some(t0)) = (a.trace.as_mut(), step_t0) {
                            tr.span_since(SpanKind::BatchStep, t0, rows as u64, n_seqs as u64);
                        }
                    }
                    RowPlan::Spec => {
                        // Acceptance scan over the run's per-row logits:
                        // greedy accepts a draft iff it equals the target
                        // argmax (so output is bit-identical to plain
                        // decode); sampled mode runs accept/resample off
                        // the request's seeded RNG. The first divergence
                        // (or the bonus position) emits the target's own
                        // token and ends the round.
                        let ActiveRequest {
                            spec,
                            sampling,
                            rng,
                            tokens,
                            events,
                            n_new,
                            pos,
                            kv,
                            pending,
                            last_logits,
                            trace,
                            ..
                        } = &mut active[ai];
                        let vocab = last_logits.len();
                        let greedy = sampling.temperature <= 0.0;
                        let sp = spec.as_mut().unwrap();
                        let m = sp.run.len() - 1;
                        let mut accepted = 0usize;
                        let mut finished: Option<FinishReason> = None;
                        for i in 0..sp.run.len() {
                            let row = scratch.logits_row_at(k, i);
                            let (tok, acc) = if greedy {
                                let t = argmax(row) as u32;
                                (t, i < m && t == sp.run[i + 1])
                            } else if i < m {
                                let q = &sp.q_rows[i * vocab..(i + 1) * vocab];
                                match spec::accept_draft(
                                    row,
                                    sampling,
                                    q,
                                    sp.run[i + 1],
                                    &mut sp.p_row,
                                    rng,
                                ) {
                                    spec::DraftDraw::Accepted => (sp.run[i + 1], true),
                                    spec::DraftDraw::Rejected(t) => (t, false),
                                }
                            } else {
                                (spec::sample_dense(row, sampling, &mut sp.p_row, rng), false)
                            };
                            tokens.push(tok);
                            metrics.tokens_out.fetch_add(1, Ordering::Relaxed);
                            metrics.spec_tokens.fetch_add(1, Ordering::Relaxed);
                            let _ = events.send(Event::Token(tok));
                            if acc {
                                accepted += 1;
                            }
                            if sampling.stop_tokens.contains(&tok) {
                                finished = Some(FinishReason::Stop);
                                break;
                            }
                            if tokens.len() >= *n_new {
                                finished = Some(FinishReason::Length);
                                break;
                            }
                            if !acc {
                                break;
                            }
                        }
                        metrics.verify_steps.fetch_add(1, Ordering::Relaxed);
                        metrics.draft_tokens.fetch_add(m, Ordering::Relaxed);
                        metrics.accepted_tokens.fetch_add(accepted, Ordering::Relaxed);
                        if let (Some(tr), Some(t0)) = (trace.as_mut(), step_t0) {
                            tr.span_since(SpanKind::SpecVerify, t0, m as u64, accepted as u64);
                        }
                        match finished {
                            Some(reason) => done.push((ai, reason)),
                            None => {
                                // Rollback: rejected-suffix positions
                                // leave both KVs; the final emitted token
                                // is pending for the next run.
                                let new_pos = *pos + 1 + accepted;
                                kv.truncate(new_pos);
                                *pos = new_pos;
                                let dlen = sp.fed.min(new_pos);
                                if let Some(dkv) = sp.kv.as_mut() {
                                    dkv.truncate(dlen);
                                }
                                sp.fed = dlen;
                                *pending = true;
                            }
                        }
                    }
                }
            }
            done.sort_unstable_by(|x, y| y.0.cmp(&x.0));
            for (ai, reason) in done.drain(..) {
                let a = active.swap_remove(ai);
                pool.release(a.slot);
                release_spec(&mut draft_pools, &a.spec);
                lock_recover(&shared.active).remove(&a.id);
                finish(a, reason, &metrics);
            }
        }
        health.round_end(*widx);
    }
}

// ---------------------------------------------------------------- sampling

// The one argmax: greedy engine output is bit-exact with
// `PackedModel::generate` only while both call the same function.
use crate::infer::model::argmax;

/// Greedy argmax when `temperature <= 0`, otherwise temperature softmax
/// over the top-k logits, drawn from the request's seeded RNG.
fn sample_token(logits: &[f32], p: &SamplingParams, rng: &mut Rng) -> u32 {
    if p.temperature <= 0.0 {
        return argmax(logits) as u32;
    }
    let k = if p.top_k == 0 { logits.len() } else { p.top_k.min(logits.len()) };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < idx.len() {
        // O(V) partition of the k largest — a full-vocab sort per decoded
        // token is wasted work when k is small.
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    // Stable softmax over the (unordered) candidate set.
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / p.temperature) as f64).exp())
        .collect();
    idx[rng.weighted(&weights)] as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_samples() {
        let p = Percentiles::of(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        assert_eq!(p.n, 10);
        // Nearest rank: ceil(50·10/100) = 5th smallest, not the 6th.
        assert_eq!(p.p50, 5.0);
        assert_eq!(p.p95, 10.0);
        assert_eq!(p.p99, 10.0);
        assert_eq!(Percentiles::of(&[]).n, 0);
        // A single sample is every percentile.
        let one = Percentiles::of(&[7.0]);
        assert_eq!((one.p50, one.p95, one.p99), (7.0, 7.0, 7.0));
        // p100-adjacent ranks stay in bounds for n = 100.
        let big: Vec<f64> = (1..=100).map(f64::from).collect();
        let pb = Percentiles::of(&big);
        assert_eq!((pb.p50, pb.p95, pb.p99), (50.0, 95.0, 99.0));
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.5];
        let mut rng = Rng::new(1);
        let p = SamplingParams::greedy();
        for _ in 0..5 {
            assert_eq!(sample_token(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_top_k_bounded() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = SamplingParams { temperature: 0.8, top_k: 4, seed: 9, stop_tokens: vec![] };
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..50).map(|_| sample_token(&logits, &p, &mut rng)).collect::<Vec<u32>>()
        };
        assert_eq!(draw(9), draw(9));
        // Every draw must come from the 4 largest logits.
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let top: Vec<u32> = order[..4].iter().map(|&i| i as u32).collect();
        assert!(draw(9).iter().all(|t| top.contains(t)));
    }

    #[test]
    fn priority_builder_sets_priority() {
        let r = GenRequest::greedy(vec![1], 4).with_priority(7);
        assert_eq!(r.priority, 7);
        assert_eq!(GenRequest::greedy(vec![1], 4).priority, 0);
    }

    #[test]
    fn deadline_builder_sets_deadline() {
        let r = GenRequest::greedy(vec![1], 4).with_deadline(Duration::from_millis(50));
        assert_eq!(r.deadline, Some(Duration::from_millis(50)));
        assert_eq!(GenRequest::greedy(vec![1], 4).deadline, None);
    }

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(vec![1u32, 2]));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the guard");
        })
        .join();
        assert!(m.lock().is_err(), "the panicking holder must have poisoned the lock");
        let mut g = lock_recover(&m);
        g.push(3);
        assert_eq!(*g, vec![1, 2, 3]);
    }

    #[test]
    fn worker_health_detects_stalls_and_recovers() {
        let h = WorkerHealth::new(2);
        let budget = Duration::from_millis(20);
        assert_eq!(h.stalled_worker(budget), None);
        h.round_begin(1);
        assert_eq!(h.stalled_worker(budget), None, "a fresh round is not a stall");
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(h.stalled_worker(budget), Some(1));
        h.round_end(1);
        assert_eq!(h.stalled_worker(budget), None, "round_end clears the heartbeat");
    }

    #[test]
    fn worker_health_fault_cooldown_window() {
        let h = WorkerHealth::new(1);
        assert!(!h.fault_within(Duration::from_secs(60)));
        h.round_begin(0);
        h.note_panic(0);
        assert!(h.fault_within(Duration::from_secs(60)));
        assert!(!h.fault_within(Duration::ZERO));
        assert_eq!(h.panics.load(Ordering::Relaxed), 1);
        assert_eq!(h.stalled_worker(Duration::ZERO), None, "note_panic clears the heartbeat");
    }

    #[test]
    fn health_state_wire_form() {
        assert_eq!(HealthState::Ready.name(), "ready");
        assert!(HealthState::Ready.is_ready());
        assert_eq!(HealthState::Ready.to_json().to_string(), "{\"status\":\"ready\"}");
        let d = HealthState::Degraded { reason: "kv pool fully charged".to_string() };
        assert!(!d.is_ready());
        assert_eq!(d.reason(), Some("kv pool fully charged"));
        // Keys render in BTreeMap order.
        assert_eq!(
            d.to_json().to_string(),
            "{\"reason\":\"kv pool fully charged\",\"status\":\"degraded\"}"
        );
        assert!(!HealthState::Draining.is_ready());
        assert_eq!(HealthState::Draining.name(), "draining");
    }
}
