//! The `Engine` session API: a persistent continuous-batching server over
//! registry-leased replicas, with streaming, sampling, cancellation and
//! bounded-queue backpressure.
//!
//! Lifecycle:
//!   * [`Engine::start`] spawns `workers` decode threads against a named
//!     model in a [`ModelRegistry`](super::ModelRegistry).  Workers acquire
//!     a [`Lease`](super::Lease) per generation at admission time, so a
//!     [`hot_swap`](super::ModelRegistry::hot_swap) is actually picked up:
//!     new admissions decode on the new generation while in-flight requests
//!     drain on the old lease (the lease drop *is* the drain barrier).
//!   * [`Engine::submit`] enforces a bounded admission queue; when it is
//!     full the caller gets [`SubmitError::QueueFull`] back immediately
//!     instead of unbounded buffering — backpressure, not memory growth.
//!   * Each accepted request returns a [`Ticket`]: a streaming event
//!     channel ([`Event::Prefilled`] / [`Event::Token`] / [`Event::Done`])
//!     plus [`Ticket::cancel`], observed between decode slices.
//!
//! Scheduling: the worker loop runs *slices* over the active set — each
//! slice advances a request by either one prefill chunk
//! ([`EngineOptions::prefill_chunk`] prompt tokens) or one decoded token —
//! so a long prompt never stalls the whole batch, and the active set
//! (prefilling + decoding) never exceeds `max_batch`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::infer::{KvCache, PackedModel};
use crate::util::rng::Rng;

use super::{Lease, ModelRegistry};

/// Per-request sampling policy. The default is greedy argmax, which
/// reproduces [`PackedModel::generate`] bit-exactly.
#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// Softmax temperature; `<= 0.0` means greedy argmax.
    pub temperature: f32,
    /// Restrict sampling to the `top_k` highest logits; `0` means the full
    /// vocabulary. Ignored under greedy.
    pub top_k: usize,
    /// Seed for the per-request [`Rng`] — outputs are deterministic per
    /// (prompt, params, seed) regardless of batching or worker count.
    pub seed: u64,
    /// Emitting any of these tokens ends the generation early (the stop
    /// token itself is included in the output).
    pub stop_tokens: Vec<u32>,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0, stop_tokens: Vec::new() }
    }
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams::default()
    }
}

/// A generation request submitted to an [`Engine`].
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<u32>,
    /// Token budget; `0` completes immediately at admission with empty
    /// output (it never reaches the decode loop, so no underflow).
    pub n_new: usize,
    pub sampling: SamplingParams,
}

impl GenRequest {
    /// Greedy request — today's default serving behavior.
    pub fn greedy(prompt: Vec<u32>, n_new: usize) -> GenRequest {
        GenRequest { prompt, n_new, sampling: SamplingParams::greedy() }
    }

    pub fn sampled(prompt: Vec<u32>, n_new: usize, sampling: SamplingParams) -> GenRequest {
        GenRequest { prompt, n_new, sampling }
    }
}

/// Why a generation ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted the full `n_new` budget.
    Length,
    /// Hit one of `stop_tokens`.
    Stop,
    /// [`Ticket::cancel`] (or engine teardown) ended it early.
    Cancelled,
}

/// Final accounting for one request, delivered in [`Event::Done`].
#[derive(Debug, Clone)]
pub struct GenStats {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub finish: FinishReason,
    /// Registry generation of the replica that served the request.
    pub generation: u64,
    /// Submission → admission into the active set.
    pub queue_wait: Duration,
    /// Submission → first emitted token (None if cancelled before one).
    pub ttft: Option<Duration>,
    /// Admission → completion.
    pub service_time: Duration,
}

/// Streaming events delivered on a [`Ticket`], in order:
/// `Prefilled`, then zero or more `Token`s, then exactly one `Done`.
#[derive(Debug, Clone)]
pub enum Event {
    /// The whole prompt has been fed through the model.
    Prefilled { prompt_len: usize },
    /// One decoded token, as soon as it exists.
    Token(u32),
    /// Terminal event; no further events follow.
    Done(GenStats),
}

/// Why [`Engine::submit`] rejected a request. The request rides back in
/// the error so backpressured callers can retry without cloning.
#[derive(Debug, Clone)]
pub enum SubmitError {
    /// The bounded admission queue is full — retry later (backpressure).
    QueueFull(GenRequest),
    /// The engine is shutting down; no new work is accepted.
    ShuttingDown(GenRequest),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(_) => write!(f, "admission queue full"),
            SubmitError::ShuttingDown(_) => write!(f, "engine shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Client handle on one submitted request: a streaming event receiver plus
/// cooperative cancellation.
pub struct Ticket {
    pub id: u64,
    events: Receiver<Event>,
    cancelled: Arc<AtomicBool>,
}

impl Ticket {
    /// Request cancellation; the worker observes it between decode slices
    /// and finishes the request with [`FinishReason::Cancelled`].
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// Blocking receive of the next event; `None` once the stream ends.
    pub fn recv(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Drain the stream to completion and return the final stats.
    pub fn wait(self) -> GenStats {
        let mut streamed = Vec::new();
        loop {
            match self.events.recv() {
                Ok(Event::Done(stats)) => return stats,
                Ok(Event::Token(t)) => streamed.push(t),
                Ok(Event::Prefilled { .. }) => {}
                // Worker died without a Done (engine torn down mid-flight):
                // surface what streamed as a cancelled result.
                Err(_) => {
                    return GenStats {
                        id: self.id,
                        tokens: streamed,
                        finish: FinishReason::Cancelled,
                        generation: 0,
                        queue_wait: Duration::ZERO,
                        ttft: None,
                        service_time: Duration::ZERO,
                    }
                }
            }
        }
    }
}

/// Latency summary (milliseconds) over recorded per-request samples.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub n: usize,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    fn of(samples: &[f64]) -> Percentiles {
        if samples.is_empty() {
            return Percentiles::default();
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let at = |q: usize| s[(s.len() * q / 100).min(s.len() - 1)];
        Percentiles { n: s.len(), p50: at(50), p95: at(95), p99: at(99) }
    }
}

/// Latency samples kept per series: a persistent engine must not grow
/// metric storage without bound, so the ring holds the most recent window
/// and percentile queries sort at most this many samples.
const LATENCY_SAMPLES: usize = 4096;

#[derive(Debug, Default)]
struct SampleRing {
    samples: Vec<f64>,
    next: usize,
}

impl SampleRing {
    fn push(&mut self, v: f64) {
        if self.samples.len() < LATENCY_SAMPLES {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
        }
        self.next = (self.next + 1) % LATENCY_SAMPLES;
    }
}

/// Aggregate serving metrics, shared by all workers of one engine.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub completed: AtomicUsize,
    pub cancelled: AtomicUsize,
    pub tokens_out: AtomicUsize,
    /// Peak concurrent active requests observed (batcher invariant probe).
    pub peak_active: AtomicUsize,
    queue_wait_ms: Mutex<SampleRing>,
    ttft_ms: Mutex<SampleRing>,
}

impl ServeMetrics {
    fn record_latency(&self, queue_wait: Duration, ttft: Option<Duration>) {
        self.queue_wait_ms.lock().unwrap().push(queue_wait.as_secs_f64() * 1e3);
        if let Some(t) = ttft {
            self.ttft_ms.lock().unwrap().push(t.as_secs_f64() * 1e3);
        }
    }

    /// p50/p95/p99 of submission → admission, in ms (most recent window).
    pub fn queue_wait_percentiles(&self) -> Percentiles {
        Percentiles::of(&self.queue_wait_ms.lock().unwrap().samples)
    }

    /// p50/p95/p99 of submission → first token, in ms (most recent window).
    pub fn ttft_percentiles(&self) -> Percentiles {
        Percentiles::of(&self.ttft_ms.lock().unwrap().samples)
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Registry name the workers serve.
    pub model: String,
    /// Max concurrent requests per worker (prefilling + decoding).
    pub max_batch: usize,
    /// Decode threads; each holds its own replica(s).
    pub workers: usize,
    /// Bounded admission queue depth; beyond it `submit` returns
    /// [`SubmitError::QueueFull`].
    pub queue_depth: usize,
    /// Prompt tokens fed per scheduling slice, so prefill interleaves with
    /// decode instead of stalling the active set.
    pub prefill_chunk: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            model: "default".into(),
            max_batch: 4,
            workers: 1,
            queue_depth: 64,
            prefill_chunk: 16,
        }
    }
}

struct Admission {
    id: u64,
    req: GenRequest,
    enqueued: Instant,
    events: Sender<Event>,
    cancelled: Arc<AtomicBool>,
}

/// Persistent serving engine. Dropping (or [`Engine::shutdown`]) closes the
/// admission queue, drains in-flight requests, and joins the workers.
pub struct Engine {
    tx: Option<SyncSender<Admission>>,
    handles: Vec<JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    next_id: AtomicU64,
}

impl Engine {
    /// Spawn the decode workers against `opts.model` in `registry`. Fails
    /// fast if no such model is registered.
    pub fn start(registry: &Arc<ModelRegistry>, opts: EngineOptions) -> Result<Engine> {
        registry
            .acquire(&opts.model)
            .ok_or_else(|| anyhow!("no model registered under {:?}", opts.model))?;
        let (tx, rx) = sync_channel(opts.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(ServeMetrics::default());
        let handles = (0..opts.workers.max(1))
            .map(|_| {
                let registry = registry.clone();
                let rx = rx.clone();
                let metrics = metrics.clone();
                let opts = opts.clone();
                std::thread::spawn(move || worker_loop(registry, rx, opts, metrics))
            })
            .collect();
        Ok(Engine { tx: Some(tx), handles, metrics, next_id: AtomicU64::new(0) })
    }

    /// Submit a request. Zero-budget requests complete immediately with
    /// empty output; otherwise the request enters the bounded queue or is
    /// rejected with [`SubmitError::QueueFull`].
    pub fn submit(&self, req: GenRequest) -> std::result::Result<Ticket, SubmitError> {
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::ShuttingDown(req));
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (etx, erx) = channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let ticket = Ticket { id, events: erx, cancelled: cancelled.clone() };
        if req.n_new == 0 {
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            let _ = etx.send(Event::Done(GenStats {
                id,
                tokens: Vec::new(),
                finish: FinishReason::Length,
                generation: 0,
                queue_wait: Duration::ZERO,
                ttft: None,
                service_time: Duration::ZERO,
            }));
            return Ok(ticket);
        }
        let adm = Admission { id, req, enqueued: Instant::now(), events: etx, cancelled };
        match tx.try_send(adm) {
            Ok(()) => Ok(ticket),
            Err(TrySendError::Full(adm)) => Err(SubmitError::QueueFull(adm.req)),
            Err(TrySendError::Disconnected(adm)) => Err(SubmitError::ShuttingDown(adm.req)),
        }
    }

    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// Stop accepting work, drain in-flight requests, join the workers.
    pub fn shutdown(mut self) -> Arc<ServeMetrics> {
        self.close();
        self.metrics.clone()
    }

    fn close(&mut self) {
        self.tx.take(); // disconnect: workers drain their active sets, then exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.close();
    }
}

// ------------------------------------------------------------------ worker

/// One leased replica a worker decodes on. Dropping the slot drops the
/// lease — that is what the registry's hot-swap drain barrier counts.
struct ReplicaSlot {
    lease: Lease,
    model: PackedModel,
    inflight: usize,
}

/// Worker-local replica pool. Requests pin the slot (generation) they were
/// admitted on; new admissions track the registry's current generation.
struct ReplicaPool {
    registry: Arc<ModelRegistry>,
    name: String,
    slots: Vec<Option<ReplicaSlot>>,
    newest: Option<usize>,
}

impl ReplicaPool {
    /// Slot serving the registry's *current* generation, cloning a fresh
    /// replica if a hot-swap moved past everything we hold. Returns `None`
    /// only when the model was removed and no replica survives.
    fn current_slot(&mut self) -> Option<usize> {
        match self.registry.acquire(&self.name) {
            Some(lease) => {
                if let Some(n) = self.newest {
                    if let Some(s) = self.slots[n].as_ref() {
                        // Entry identity, not generation number: a
                        // remove+re-register resets the per-name counter,
                        // so equal numbers can name different weights.
                        if Arc::ptr_eq(s.lease.entry(), lease.entry()) {
                            return Some(n); // probe lease drops here
                        }
                    }
                }
                let model = lease.replica();
                let slot = ReplicaSlot { lease, model, inflight: 0 };
                let idx = match self.slots.iter().position(|s| s.is_none()) {
                    Some(i) => {
                        self.slots[i] = Some(slot);
                        i
                    }
                    None => {
                        self.slots.push(Some(slot));
                        self.slots.len() - 1
                    }
                };
                if let Some(prev) = self.newest {
                    if prev != idx {
                        self.retire_if_idle(prev);
                    }
                }
                self.newest = Some(idx);
                Some(idx)
            }
            // Removed from the registry: keep draining on the newest
            // surviving replica (the lease keeps its weights alive).
            None => self.newest.filter(|&n| self.slots[n].is_some()),
        }
    }

    /// One request on `idx` finished; drop the slot (and its lease) once it
    /// is idle and superseded. The newest slot is kept without probing the
    /// registry — a swap that outran it is caught by the next admission
    /// (`current_slot`) or by idle housekeeping (`drop_idle_stale`), so the
    /// common no-swap completion pays no registry round-trip.
    fn release(&mut self, idx: usize) {
        let Some(s) = self.slots[idx].as_mut() else { return };
        s.inflight -= 1;
        if s.inflight == 0 && Some(idx) != self.newest {
            self.drop_slot(idx);
        }
    }

    /// Idle housekeeping: release leases a hot-swap (or removal) has moved
    /// past, so a drain barrier is not held open by an idle worker.
    fn drop_idle_stale(&mut self) {
        for idx in 0..self.slots.len() {
            let idle = self.slots[idx].as_ref().is_some_and(|s| s.inflight == 0);
            if idle && (Some(idx) != self.newest || self.entry_stale(idx)) {
                self.drop_slot(idx);
            }
        }
    }

    /// Does the registry currently serve a different entry than `idx` holds?
    fn entry_stale(&self, idx: usize) -> bool {
        let held = self.slots[idx].as_ref().unwrap().lease.entry();
        match self.registry.acquire(&self.name) {
            Some(current) => !Arc::ptr_eq(held, current.entry()),
            None => true, // model removed: holding the lease serves nothing
        }
    }

    fn drop_slot(&mut self, idx: usize) {
        self.slots[idx] = None;
        if Some(idx) == self.newest {
            self.newest = None;
        }
    }

    fn retire_if_idle(&mut self, idx: usize) {
        if self.slots[idx].as_ref().is_some_and(|s| s.inflight == 0) {
            self.drop_slot(idx);
        }
    }
}

/// One in-flight request: its own caches, RNG, and event stream; pinned to
/// the replica slot it was admitted on.
struct ActiveRequest {
    id: u64,
    prompt: Vec<u32>,
    n_new: usize,
    sampling: SamplingParams,
    rng: Rng,
    tokens: Vec<u32>,
    last_logits: Vec<f32>,
    /// Prompt tokens fed so far; prefill is done when it reaches
    /// `prompt.len()`.
    prefill_pos: usize,
    pos: usize,
    caches: Vec<KvCache>,
    slot: usize,
    generation: u64,
    enqueued: Instant,
    started: Instant,
    first_token: Option<Duration>,
    events: Sender<Event>,
    cancelled: Arc<AtomicBool>,
}

fn finish(a: ActiveRequest, reason: FinishReason, metrics: &ServeMetrics) {
    let queue_wait = a.started - a.enqueued;
    match reason {
        FinishReason::Cancelled => metrics.cancelled.fetch_add(1, Ordering::Relaxed),
        _ => metrics.completed.fetch_add(1, Ordering::Relaxed),
    };
    metrics.record_latency(queue_wait, a.first_token);
    let _ = a.events.send(Event::Done(GenStats {
        id: a.id,
        tokens: a.tokens,
        finish: reason,
        generation: a.generation,
        queue_wait,
        ttft: a.first_token,
        service_time: a.started.elapsed(),
    }));
}

/// Reject an admission that never reached the active set.
fn reject(adm: Admission, metrics: &ServeMetrics) {
    metrics.cancelled.fetch_add(1, Ordering::Relaxed);
    let _ = adm.events.send(Event::Done(GenStats {
        id: adm.id,
        tokens: Vec::new(),
        finish: FinishReason::Cancelled,
        generation: 0,
        queue_wait: adm.enqueued.elapsed(),
        ttft: None,
        service_time: Duration::ZERO,
    }));
}

fn worker_loop(
    registry: Arc<ModelRegistry>,
    rx: Arc<Mutex<Receiver<Admission>>>,
    opts: EngineOptions,
    metrics: Arc<ServeMetrics>,
) {
    let max_batch = opts.max_batch.max(1);
    let prefill_chunk = opts.prefill_chunk.max(1);
    let mut pool = ReplicaPool {
        registry,
        name: opts.model.clone(),
        slots: Vec::new(),
        newest: None,
    };
    let mut active: Vec<ActiveRequest> = Vec::new();
    let mut closed = false;
    loop {
        // ---- admission: fill free batch slots from the shared queue ----
        while active.len() < max_batch && !closed {
            // Never hold the queue lock across a blocking wait: an idle
            // worker parked inside the Mutex would stall every sibling's
            // admission check (which runs once per decode slice).
            let polled = {
                let rx = rx.lock().unwrap();
                match rx.try_recv() {
                    Ok(adm) => Some(adm),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        closed = true;
                        None
                    }
                }
            };
            let Some(adm) = polled else { break };
            if adm.cancelled.load(Ordering::Relaxed) {
                reject(adm, &metrics);
                continue;
            }
            let Some(slot) = pool.current_slot() else {
                reject(adm, &metrics); // model gone, nothing to drain on
                continue;
            };
            let started = Instant::now();
            let (generation, vocab, caches) = {
                let s = pool.slots[slot].as_mut().unwrap();
                s.inflight += 1;
                let max_seq = adm.req.prompt.len() + adm.req.n_new + 1;
                (s.lease.generation, s.model.cfg.vocab, s.model.new_caches(max_seq))
            };
            if adm.req.prompt.is_empty() {
                let _ = adm.events.send(Event::Prefilled { prompt_len: 0 });
            }
            active.push(ActiveRequest {
                id: adm.id,
                rng: Rng::new(adm.req.sampling.seed),
                tokens: Vec::with_capacity(adm.req.n_new),
                last_logits: vec![0.0; vocab],
                prefill_pos: 0,
                pos: 0,
                caches,
                slot,
                generation,
                enqueued: adm.enqueued,
                started,
                first_token: None,
                events: adm.events,
                cancelled: adm.cancelled,
                prompt: adm.req.prompt,
                n_new: adm.req.n_new,
                sampling: adm.req.sampling,
            });
            metrics.peak_active.fetch_max(active.len(), Ordering::Relaxed);
        }
        if active.is_empty() {
            pool.drop_idle_stale();
            if closed {
                return;
            }
            // Idle backoff outside the queue lock (see admission above).
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }
        // ---- one slice per active: a prefill chunk or one decoded token --
        let mut i = 0;
        while i < active.len() {
            if active[i].cancelled.load(Ordering::Relaxed) {
                let a = active.swap_remove(i);
                pool.release(a.slot);
                finish(a, FinishReason::Cancelled, &metrics);
                continue;
            }
            let slot = active[i].slot;
            let model = &mut pool.slots[slot].as_mut().unwrap().model;
            let a = &mut active[i];
            if a.prefill_pos < a.prompt.len() {
                let end = (a.prefill_pos + prefill_chunk).min(a.prompt.len());
                for pos in a.prefill_pos..end {
                    a.last_logits = model.decode_step(a.prompt[pos], pos, &mut a.caches);
                }
                a.prefill_pos = end;
                if end == a.prompt.len() {
                    a.pos = end;
                    let _ = a.events.send(Event::Prefilled { prompt_len: end });
                }
                i += 1;
                continue;
            }
            let next = sample_token(&a.last_logits, &a.sampling, &mut a.rng);
            a.tokens.push(next);
            if a.first_token.is_none() {
                a.first_token = Some(a.enqueued.elapsed());
            }
            metrics.tokens_out.fetch_add(1, Ordering::Relaxed);
            let _ = a.events.send(Event::Token(next));
            let stopped = a.sampling.stop_tokens.contains(&next);
            if stopped || a.tokens.len() >= a.n_new {
                let a = active.swap_remove(i);
                pool.release(a.slot);
                finish(a, if stopped { FinishReason::Stop } else { FinishReason::Length }, &metrics);
            } else {
                a.last_logits = model.decode_step(next, a.pos, &mut a.caches);
                a.pos += 1;
                i += 1;
            }
        }
    }
}

// ---------------------------------------------------------------- sampling

// The one argmax: greedy engine output is bit-exact with
// `PackedModel::generate` only while both call the same function.
use crate::infer::model::argmax;

/// Greedy argmax when `temperature <= 0`, otherwise temperature softmax
/// over the top-k logits, drawn from the request's seeded RNG.
fn sample_token(logits: &[f32], p: &SamplingParams, rng: &mut Rng) -> u32 {
    if p.temperature <= 0.0 {
        return argmax(logits) as u32;
    }
    let k = if p.top_k == 0 { logits.len() } else { p.top_k.min(logits.len()) };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < idx.len() {
        // O(V) partition of the k largest — a full-vocab sort per decoded
        // token is wasted work when k is small.
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    // Stable softmax over the (unordered) candidate set.
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / p.temperature) as f64).exp())
        .collect();
    idx[rng.weighted(&weights)] as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_samples() {
        let p = Percentiles::of(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        assert_eq!(p.n, 10);
        assert_eq!(p.p50, 6.0);
        assert_eq!(p.p95, 10.0);
        assert_eq!(p.p99, 10.0);
        assert_eq!(Percentiles::of(&[]).n, 0);
    }

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.5];
        let mut rng = Rng::new(1);
        let p = SamplingParams::greedy();
        for _ in 0..5 {
            assert_eq!(sample_token(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_top_k_bounded() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = SamplingParams { temperature: 0.8, top_k: 4, seed: 9, stop_tokens: vec![] };
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..50).map(|_| sample_token(&logits, &p, &mut rng)).collect::<Vec<u32>>()
        };
        assert_eq!(draw(9), draw(9));
        // Every draw must come from the 4 largest logits.
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let top: Vec<u32> = order[..4].iter().map(|&i| i as u32).collect();
        assert!(draw(9).iter().all(|t| top.contains(t)));
    }
}
