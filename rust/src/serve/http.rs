//! HTTP/1.1 + SSE serving front end — the engine's network surface.
//!
//! A dependency-free threaded server over [`std::net::TcpListener`] (the
//! offline crate set has no tokio/hyper): one accept thread, one handler
//! thread per connection, JSON via [`crate::util::json`]. Routes:
//!
//!   * `POST /v1/generate` — JSON body → [`GenRequest`], response streamed
//!     as Server-Sent Events (`prefilled` / `token` / `done` frames). A
//!     client disconnect mid-stream cancels the request via
//!     [`Ticket::cancel`] and drains it, so its worker slot and KV blocks
//!     are freed. Backpressure maps to HTTP: `QueueFull` → 429 and
//!     `KvExhausted` → 503, both with a `Retry-After` header (and a
//!     `retry_after_ms` body field) carrying the engine's typed
//!     [`RetryAfter`] guidance; `KvTooLarge` → 413, draft rejections and
//!     malformed bodies → 400.
//!   * `GET /v1/metrics` — [`ServeMetrics::to_json`] snapshot per routed
//!     engine, plus this front end's own per-route request/error counters
//!     under `"http"`. Content negotiation: `Accept: text/plain` (or
//!     `application/openmetrics-text`), or `?format=prometheus`, switches
//!     the response to the Prometheus text exposition rendered via
//!     [`crate::obs::prom::Exposition`]; JSON stays the default.
//!   * `GET /v1/trace/<id|latest|all>` — a completed request's spans (or
//!     the engine's whole completed-trace ring plus the KV event track)
//!     as Chrome trace-event JSON, when the routed engine runs with
//!     tracing enabled; `?model=NAME` picks a non-default engine.
//!   * `GET /v1/models` — the [`ModelRegistry`] listing.
//!   * `GET /v1/health` — the worst [`HealthState`] across routed engines
//!     (`ready` / `degraded` / `draining`): 200 only when every engine is
//!     Ready, 503 otherwise, with per-engine detail in the body. Wired
//!     for load-balancer probes; see `docs/robustness.md`.
//!
//! Requests route to an engine by the optional `"model"` body key (the
//! [`Router`] maps model names to engines; the first added is the
//! default), may request speculative decoding with
//! `"draft_model"`/`"spec_k"` (resolved against the registry at submit
//! time), and may set an end-to-end deadline with `"deadline_ms"`
//! ([`GenRequest::with_deadline`]; past it the request finishes with a
//! `"deadline"` done frame). [`HttpServer::shutdown`] stops accepting, 503s new generate
//! requests, and joins every in-flight handler — live streams drain to
//! their `done` frame. See `docs/serving.md` for the wire format.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::obs::prom::Exposition;
use crate::util::json::{arr, num, obj, s, Json};

use super::engine::lock_recover;
use super::{
    Engine, Event, FinishReason, GenRequest, HealthState, ModelRegistry, SamplingParams,
    SubmitError, Ticket,
};

/// How long the SSE loop waits for the next engine event before probing
/// the socket for a client disconnect.
const EVENT_POLL: Duration = Duration::from_millis(20);
/// Header-read timeout: a connection that never finishes its request line
/// must not pin a handler thread forever.
const HEADER_TIMEOUT: Duration = Duration::from_secs(5);
/// Caps on untrusted input.
const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;
/// Ceiling on live handler threads: above it new connections bounce with
/// 503 + `Retry-After` instead of spawning without bound.
const MAX_CONNS: usize = 256;
/// SSE write budget: a client that stops reading long enough for the
/// socket buffer to fill *and* this timeout to pass is treated exactly
/// like a disconnect (cancel + drain), so one stalled reader can never
/// pin a handler thread and its KV blocks indefinitely.
const SSE_WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Maps request `"model"` keys to engines. One engine serves one registry
/// name, so a multi-model server runs one engine per served name; the
/// first route added is the default for bodies without a `"model"` key.
pub struct Router {
    registry: Arc<ModelRegistry>,
    routes: Vec<(String, Arc<Engine>)>,
}

impl Router {
    pub fn new(registry: Arc<ModelRegistry>) -> Router {
        Router { registry, routes: Vec::new() }
    }

    /// Route `name` to `engine`; the first route added becomes the
    /// default. Builder-style so tests read as one expression.
    pub fn route(mut self, name: impl Into<String>, engine: Arc<Engine>) -> Router {
        self.routes.push((name.into(), engine));
        self
    }

    /// Resolve a request's `model` key; `None` key means the default.
    fn engine(&self, name: Option<&str>) -> Option<&Arc<Engine>> {
        match name {
            None => self.routes.first().map(|(_, e)| e),
            Some(n) => self.routes.iter().find(|(name, _)| name == n).map(|(_, e)| e),
        }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }
}

struct ServerState {
    router: Router,
    stopping: AtomicBool,
    stats: HttpStats,
}

/// One route's request/error tally.
struct RouteStats {
    name: &'static str,
    requests: AtomicUsize,
    errors: AtomicUsize,
}

impl RouteStats {
    fn new(name: &'static str) -> RouteStats {
        RouteStats { name, requests: AtomicUsize::new(0), errors: AtomicUsize::new(0) }
    }

    fn note_err(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// The front end's own per-route counters, reported under the `"http"`
/// key of the JSON metrics snapshot and as `http_requests_total` /
/// `http_errors_total{route=..}` in the Prometheus exposition.
struct HttpStats {
    routes: [RouteStats; 6],
}

impl HttpStats {
    fn new() -> HttpStats {
        HttpStats {
            routes: [
                RouteStats::new("generate"),
                RouteStats::new("metrics"),
                RouteStats::new("models"),
                RouteStats::new("trace"),
                RouteStats::new("health"),
                RouteStats::new("other"),
            ],
        }
    }

    fn route(&self, name: &str) -> &RouteStats {
        self.routes
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| self.routes.last().unwrap())
    }

    fn to_json(&self) -> Json {
        let pairs: Vec<(&str, Json)> = self
            .routes
            .iter()
            .map(|r| {
                (
                    r.name,
                    obj(vec![
                        ("requests", num(r.requests.load(Ordering::Relaxed) as f64)),
                        ("errors", num(r.errors.load(Ordering::Relaxed) as f64)),
                    ]),
                )
            })
            .collect();
        obj(pairs)
    }

    fn render_prometheus(&self, ex: &mut Exposition) {
        for r in &self.routes {
            let labels = [("route", r.name)];
            ex.counter(
                "http_requests_total",
                "front-end requests by route",
                &labels,
                r.requests.load(Ordering::Relaxed) as f64,
            );
            ex.counter(
                "http_errors_total",
                "front-end error responses by route",
                &labels,
                r.errors.load(Ordering::Relaxed) as f64,
            );
        }
    }
}

/// The serving front end: accept loop + per-connection handler threads.
/// Dropping (or [`HttpServer::shutdown`]) stops accepting and joins every
/// in-flight handler, draining live SSE streams.
pub struct HttpServer {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, or port 0 for an ephemeral
    /// test port) and start serving `router`'s engines.
    pub fn bind(addr: &str, router: Router) -> Result<HttpServer> {
        if router.routes.is_empty() {
            return Err(anyhow!("router has no engines"));
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let state = Arc::new(ServerState {
            router,
            stopping: AtomicBool::new(false),
            stats: HttpStats::new(),
        });
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let state = state.clone();
            let conns = conns.clone();
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    if state.stopping.load(Ordering::Acquire) {
                        break; // the shutdown self-connect lands here too
                    }
                    let Ok(mut stream) = stream else { continue };
                    let mut conns = lock_recover(&conns);
                    // Reap finished handlers so a long-lived server does
                    // not accumulate one JoinHandle per past request.
                    conns.retain(|h| !h.is_finished());
                    if conns.len() >= MAX_CONNS {
                        // Handler threads are the resource being guarded:
                        // shed the connection here, before spawning one.
                        drop(conns);
                        let row = state.stats.route("other");
                        row.requests.fetch_add(1, Ordering::Relaxed);
                        row.note_err();
                        respond_backpressure(
                            &mut stream,
                            503,
                            "connection limit reached",
                            Duration::from_millis(50),
                        );
                        continue;
                    }
                    let state = state.clone();
                    conns.push(std::thread::spawn(move || handle_connection(stream, &state)));
                }
            })
        };
        Ok(HttpServer { addr: local, state, accept: Some(accept), conns })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, reject new generate requests
    /// with 503, and block until every in-flight stream has drained.
    pub fn shutdown(mut self) {
        self.close();
    }

    fn close(&mut self) {
        if self
            .state
            .stopping
            .swap(true, Ordering::AcqRel)
        {
            return;
        }
        // Unblock the accept loop (it re-checks `stopping` per connection).
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *lock_recover(&self.conns));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.close();
    }
}

// ------------------------------------------------------------ HTTP plumbing

struct Request {
    method: String,
    path: String,
    headers: HashMap<String, String>,
    body: Vec<u8>,
}

/// Read one HTTP/1.1 request (request line, headers, Content-Length body).
fn read_request(stream: &mut TcpStream) -> std::io::Result<Request> {
    stream.set_read_timeout(Some(HEADER_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(std::io::Error::new(ErrorKind::InvalidData, "bad request line"));
    }
    let mut headers = HashMap::new();
    let mut header_bytes = line.len();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        header_bytes += h.len();
        if header_bytes > MAX_HEADER_BYTES {
            return Err(std::io::Error::new(ErrorKind::InvalidData, "headers too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(std::io::Error::new(ErrorKind::InvalidData, "body too large"));
    }
    // curl sends Expect: 100-continue before large bodies and waits for
    // the interim response.
    if headers.get("expect").is_some_and(|v| v.eq_ignore_ascii_case("100-continue")) {
        reader.get_ref().try_clone()?.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, headers, body })
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    }
}

/// One-shot JSON response (everything except the SSE stream).
fn respond_json(stream: &mut TcpStream, code: u16, extra: &[(&str, String)], body: &Json) {
    let payload = body.to_string();
    let mut head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n",
        status_text(code),
        payload.len()
    );
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(payload.as_bytes());
    let _ = stream.flush();
}

/// One-shot plain-text response (the Prometheus exposition).
fn respond_text(stream: &mut TcpStream, code: u16, content_type: &str, body: &str) {
    let head = format!(
        "HTTP/1.1 {code} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        status_text(code),
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

fn respond_error(stream: &mut TcpStream, code: u16, msg: &str) {
    respond_json(stream, code, &[], &obj(vec![("error", s(msg))]));
}

/// 429/503 with the engine's typed retry guidance: a `Retry-After` header
/// (integer seconds, floored at 1 as HTTP requires) plus the precise
/// `retry_after_ms` in the body for clients that can sleep sub-second.
fn respond_backpressure(stream: &mut TcpStream, code: u16, msg: &str, retry_after: Duration) {
    let secs = retry_after.as_secs_f64().ceil().max(1.0) as u64;
    respond_json(
        stream,
        code,
        &[("Retry-After", secs.to_string())],
        &obj(vec![
            ("error", s(msg)),
            ("retry_after_ms", num(retry_after.as_secs_f64() * 1e3)),
        ]),
    );
}

// ------------------------------------------------------------------ routes

/// Which counter bucket a request lands in.
fn route_name(method: &str, path: &str) -> &'static str {
    match (method, path) {
        ("POST", "/v1/generate") | ("GET", "/v1/generate") => "generate",
        ("GET", "/v1/metrics") => "metrics",
        ("GET", "/v1/models") => "models",
        ("GET", "/v1/health") => "health",
        ("GET", p) if p.starts_with("/v1/trace/") => "trace",
        _ => "other",
    }
}

/// Look up `key` in a raw query string (`a=1&b=2`).
fn query_param<'a>(query: Option<&'a str>, key: &str) -> Option<&'a str> {
    query?.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
        (k == key).then_some(v)
    })
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    let _ = stream.set_nodelay(true);
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(_) => {
            let row = state.stats.route("other");
            row.requests.fetch_add(1, Ordering::Relaxed);
            row.note_err();
            respond_error(&mut stream, 400, "malformed HTTP request");
            return;
        }
    };
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (req.path.as_str(), None),
    };
    let row = state.stats.route(route_name(&req.method, path));
    row.requests.fetch_add(1, Ordering::Relaxed);
    match (req.method.as_str(), path) {
        ("POST", "/v1/generate") => handle_generate(stream, state, &req, row),
        ("GET", "/v1/models") => {
            let models: Vec<Json> = state
                .router
                .registry
                .info()
                .into_iter()
                .map(|m| {
                    obj(vec![
                        ("name", s(&m.name)),
                        ("generation", num(m.generation as f64)),
                        ("variant", s(m.variant.name())),
                        ("params", num(m.params as f64)),
                        ("storage_bytes", num(m.storage_bytes as f64)),
                        ("has_tokenizer", Json::Bool(m.has_tokenizer)),
                        (
                            "routed",
                            Json::Bool(state.router.routes.iter().any(|(n, _)| *n == m.name)),
                        ),
                    ])
                })
                .collect();
            respond_json(&mut stream, 200, &[], &obj(vec![("models", arr(models))]));
        }
        ("GET", "/v1/metrics") => handle_metrics(stream, state, &req, query),
        ("GET", "/v1/health") => handle_health(stream, state, row),
        ("GET", p) if p.starts_with("/v1/trace/") => handle_trace(stream, state, p, query, row),
        ("GET", "/v1/generate") => {
            row.note_err();
            respond_error(&mut stream, 405, "use POST /v1/generate");
        }
        _ => {
            row.note_err();
            respond_error(&mut stream, 404, "unknown route");
        }
    }
}

/// Does this metrics request want the Prometheus text exposition instead
/// of JSON? Either an explicit `?format=prometheus` or an `Accept` header
/// preferring a text format.
fn wants_prometheus(req: &Request, query: Option<&str>) -> bool {
    if let Some(fmt) = query_param(query, "format") {
        return fmt.eq_ignore_ascii_case("prometheus") || fmt.eq_ignore_ascii_case("text");
    }
    req.headers
        .get("accept")
        .is_some_and(|a| a.contains("text/plain") || a.contains("openmetrics"))
}

fn handle_metrics(mut stream: TcpStream, state: &ServerState, req: &Request, query: Option<&str>) {
    if wants_prometheus(req, query) {
        let mut ex = Exposition::new("pquant_");
        for (name, engine) in &state.router.routes {
            engine.metrics().render_prometheus(&mut ex, name);
        }
        state.stats.render_prometheus(&mut ex);
        respond_text(
            &mut stream,
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            &ex.render(),
        );
        return;
    }
    let mut per_engine: Vec<(&str, Json)> = state
        .router
        .routes
        .iter()
        .map(|(name, engine)| (name.as_str(), engine.metrics().to_json()))
        .collect();
    per_engine.push(("http", state.stats.to_json()));
    respond_json(&mut stream, 200, &[], &obj(per_engine));
}

/// Rank for worst-of aggregation: draining > degraded > ready.
fn health_severity(h: &HealthState) -> u8 {
    match h {
        HealthState::Ready => 0,
        HealthState::Degraded { .. } => 1,
        HealthState::Draining => 2,
    }
}

/// `GET /v1/health` — 200 only when every routed engine is Ready; 503
/// for degraded (still serving — prefer another replica) and draining.
/// Body: the overall state plus a per-engine breakdown.
fn handle_health(mut stream: TcpStream, state: &ServerState, stats: &RouteStats) {
    let per_engine: Vec<(&str, HealthState)> = state
        .router
        .routes
        .iter()
        .map(|(name, e)| (name.as_str(), e.health()))
        .collect();
    // A stopping front end is draining regardless of engine state (its
    // engines only learn on their own shutdown); otherwise the server is
    // as healthy as its sickest engine.
    let overall = if state.stopping.load(Ordering::Acquire) {
        HealthState::Draining
    } else {
        per_engine
            .iter()
            .map(|(_, h)| h.clone())
            .max_by_key(health_severity)
            .unwrap_or(HealthState::Ready)
    };
    let code = if overall.is_ready() { 200 } else { 503 };
    if code != 200 {
        stats.note_err();
    }
    let mut pairs = vec![("status", s(overall.name()))];
    if let Some(r) = overall.reason() {
        pairs.push(("reason", s(r)));
    }
    pairs.push(("engines", obj(per_engine.iter().map(|(n, h)| (*n, h.to_json())).collect())));
    respond_json(&mut stream, code, &[], &obj(pairs));
}

/// `GET /v1/trace/<id|latest|all>` — Chrome trace-event JSON for one
/// completed request (or the engine's whole ring, `all`). 404s when the
/// routed engine runs without tracing or the id has left the ring.
fn handle_trace(
    mut stream: TcpStream,
    state: &ServerState,
    path: &str,
    query: Option<&str>,
    stats: &RouteStats,
) {
    let selector = &path["/v1/trace/".len()..];
    let Some(engine) = state.router.engine(query_param(query, "model")) else {
        stats.note_err();
        respond_error(&mut stream, 404, "no engine routed for that model");
        return;
    };
    let Some(tr) = engine.metrics().trace() else {
        stats.note_err();
        respond_error(&mut stream, 404, "tracing is disabled on this engine (serve --trace)");
        return;
    };
    let doc = match selector {
        "all" => Some(tr.to_chrome_json()),
        "latest" => tr.latest().map(|t| t.to_chrome_json(tr.epoch_unix_us())),
        id => match id.parse::<u64>() {
            Ok(id) => tr.find(id).map(|t| t.to_chrome_json(tr.epoch_unix_us())),
            Err(_) => {
                stats.note_err();
                respond_error(&mut stream, 400, "trace id must be an integer, \"latest\", or \"all\"");
                return;
            }
        },
    };
    match doc {
        Some(j) => respond_json(&mut stream, 200, &[], &j),
        None => {
            stats.note_err();
            respond_error(&mut stream, 404, "no completed trace under that id");
        }
    }
}

/// Parsed `POST /v1/generate` body.
struct GenerateBody {
    model: Option<String>,
    req: GenRequest,
}

fn parse_generate(state: &ServerState, body: &[u8]) -> std::result::Result<GenerateBody, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let j = Json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let model = j.opt("model").map(|m| m.as_str().map(str::to_string)).transpose()
        .map_err(|_| "\"model\" must be a string".to_string())?;
    // Prompt: either explicit token ids, or text encoded with the routed
    // model's embedded tokenizer.
    let prompt: Vec<u32> = match (j.opt("prompt"), j.opt("text")) {
        (Some(p), _) => p
            .as_arr()
            .map_err(|_| "\"prompt\" must be an array of token ids".to_string())?
            .iter()
            .map(|t| t.as_usize().map(|v| v as u32))
            .collect::<anyhow::Result<_>>()
            .map_err(|_| "\"prompt\" must be non-negative integers".to_string())?,
        (None, Some(t)) => {
            let text = t.as_str().map_err(|_| "\"text\" must be a string".to_string())?;
            let name = model.as_deref().unwrap_or_else(|| {
                state.router.routes.first().map(|(n, _)| n.as_str()).unwrap_or("")
            });
            let lease = state
                .router
                .registry
                .acquire(name)
                .ok_or_else(|| format!("unknown model {name:?}"))?;
            match lease.tokenizer.as_ref() {
                Some(bpe) => bpe.encode(text),
                None => return Err(format!("model {name:?} has no embedded tokenizer")),
            }
        }
        (None, None) => return Err("body needs \"prompt\" (token ids) or \"text\"".to_string()),
    };
    let n_new = match j.opt("n_new").or_else(|| j.opt("max_tokens")) {
        Some(v) => v.as_usize().map_err(|_| "\"n_new\" must be a non-negative integer".to_string())?,
        None => 16,
    };
    let f64_key = |key: &str, default: f64| -> std::result::Result<f64, String> {
        match j.opt(key) {
            Some(v) => v.as_f64().map_err(|_| format!("{key:?} must be a number")),
            None => Ok(default),
        }
    };
    let usize_key = |key: &str, default: usize| -> std::result::Result<usize, String> {
        match j.opt(key) {
            Some(v) => v.as_usize().map_err(|_| format!("{key:?} must be a non-negative integer")),
            None => Ok(default),
        }
    };
    let stop_tokens: Vec<u32> = match j.opt("stop_tokens") {
        Some(v) => v
            .as_arr()
            .map_err(|_| "\"stop_tokens\" must be an array".to_string())?
            .iter()
            .map(|t| t.as_usize().map(|v| v as u32))
            .collect::<anyhow::Result<_>>()
            .map_err(|_| "\"stop_tokens\" must be non-negative integers".to_string())?,
        None => Vec::new(),
    };
    let sampling = SamplingParams {
        temperature: f64_key("temperature", 0.0)? as f32,
        top_k: usize_key("top_k", 0)?,
        seed: usize_key("seed", 0)? as u64,
        stop_tokens,
    };
    let priority = match j.opt("priority") {
        Some(v) => v.as_f64().map_err(|_| "\"priority\" must be a number".to_string())? as i32,
        None => 0,
    };
    let mut req = GenRequest::sampled(prompt, n_new, sampling).with_priority(priority);
    if let Some(v) = j.opt("deadline_ms") {
        let ms = v.as_f64().map_err(|_| "\"deadline_ms\" must be a number".to_string())?;
        if ms.is_nan() || ms < 0.0 {
            return Err("\"deadline_ms\" must be non-negative".to_string());
        }
        req = req.with_deadline(Duration::from_secs_f64(ms / 1e3));
    }
    if let Some(d) = j.opt("draft_model") {
        let draft = d.as_str().map_err(|_| "\"draft_model\" must be a string".to_string())?;
        req = req.with_spec(draft, usize_key("spec_k", 4)?);
    }
    Ok(GenerateBody { model, req })
}

fn handle_generate(mut stream: TcpStream, state: &ServerState, req: &Request, stats: &RouteStats) {
    if state.stopping.load(Ordering::Acquire) {
        stats.note_err();
        respond_error(&mut stream, 503, "server shutting down");
        return;
    }
    if !req
        .headers
        .get("content-type")
        .map_or(true, |t| t.starts_with("application/json"))
    {
        stats.note_err();
        respond_error(&mut stream, 400, "Content-Type must be application/json");
        return;
    }
    let parsed = match parse_generate(state, &req.body) {
        Ok(p) => p,
        Err(msg) => {
            stats.note_err();
            respond_error(&mut stream, 400, &msg);
            return;
        }
    };
    let Some(engine) = state.router.engine(parsed.model.as_deref()) else {
        stats.note_err();
        respond_error(
            &mut stream,
            404,
            &format!("no engine routed for model {:?}", parsed.model.as_deref().unwrap_or("?")),
        );
        return;
    };
    let ticket = match engine.submit(parsed.req) {
        Ok(t) => t,
        Err(e @ SubmitError::QueueFull(..)) => {
            let ra = e.retry_after().unwrap_or(Duration::from_millis(25));
            stats.note_err();
            respond_backpressure(&mut stream, 429, &e.to_string(), ra);
            return;
        }
        Err(e @ SubmitError::KvExhausted(..)) => {
            let ra = e.retry_after().unwrap_or(Duration::from_millis(25));
            stats.note_err();
            respond_backpressure(&mut stream, 503, &e.to_string(), ra);
            return;
        }
        Err(e @ SubmitError::KvTooLarge(_)) => {
            stats.note_err();
            respond_error(&mut stream, 413, &e.to_string());
            return;
        }
        Err(e @ SubmitError::DraftRejected(..)) => {
            stats.note_err();
            respond_error(&mut stream, 400, &e.to_string());
            return;
        }
        Err(e @ SubmitError::ShuttingDown(_)) => {
            stats.note_err();
            respond_error(&mut stream, 503, &e.to_string());
            return;
        }
    };
    stream_sse(stream, ticket);
}

// ----------------------------------------------------------------- the SSE

fn sse_frame(event: &str, data: &Json) -> String {
    format!("event: {event}\ndata: {}\n\n", data.to_string())
}

fn finish_name(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Failed => "failed",
        FinishReason::WorkerFault => "worker_fault",
        FinishReason::DeadlineExceeded => "deadline",
    }
}

/// Has the peer closed its end? Probed between engine events with a tiny
/// read timeout: `Ok(0)` is EOF (client gone), `WouldBlock`/`TimedOut`
/// means it is still there. Request bytes the client pipelines after the
/// body are ignored.
fn client_gone(stream: &mut TcpStream) -> bool {
    let mut buf = [0u8; 64];
    if stream.set_read_timeout(Some(Duration::from_millis(1))).is_err() {
        return true;
    }
    match stream.read(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut),
    }
}

/// Stream one ticket as SSE. On client disconnect (probe or failed write)
/// the request is cancelled *and drained to its terminal event*, so the
/// engine has already released its worker slot and KV blocks by the time
/// this handler returns.
fn stream_sse(mut stream: TcpStream, ticket: Ticket) {
    // A full socket buffer must not block this thread forever: past the
    // write budget the client counts as gone (see `SSE_WRITE_TIMEOUT`).
    let _ = stream.set_write_timeout(Some(SSE_WRITE_TIMEOUT));
    let header = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n";
    if stream.write_all(header.as_bytes()).is_err() {
        cancel_and_drain(&ticket);
        return;
    }
    let mut index = 0usize;
    loop {
        let event = match ticket.recv_timeout(EVENT_POLL) {
            Ok(ev) => ev,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if client_gone(&mut stream) {
                    cancel_and_drain(&ticket);
                    return;
                }
                continue;
            }
            // Engine torn down without a Done; nothing more will arrive.
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        };
        let frame = match &event {
            Event::Prefilled { prompt_len } => sse_frame(
                "prefilled",
                &obj(vec![("prompt_len", num(*prompt_len as f64))]),
            ),
            Event::Token(t) => {
                let f = sse_frame(
                    "token",
                    &obj(vec![("token", num(*t as f64)), ("index", num(index as f64))]),
                );
                index += 1;
                f
            }
            Event::Done(stats) => sse_frame(
                "done",
                &obj(vec![
                    ("finish", s(finish_name(stats.finish))),
                    ("n_tokens", num(stats.tokens.len() as f64)),
                    ("tokens", arr(stats.tokens.iter().map(|&t| num(t as f64)))),
                    ("generation", num(stats.generation as f64)),
                    ("queue_wait_ms", num(stats.queue_wait.as_secs_f64() * 1e3)),
                    (
                        "ttft_ms",
                        match stats.ttft {
                            Some(t) => num(t.as_secs_f64() * 1e3),
                            None => Json::Null,
                        },
                    ),
                    ("service_ms", num(stats.service_time.as_secs_f64() * 1e3)),
                ]),
            ),
        };
        // The sse.write failpoint models a mid-stream socket death (or a
        // reader stalled past the write budget) without needing a real
        // misbehaving peer.
        let failed = crate::failpoint!("sse.write")
            || stream.write_all(frame.as_bytes()).is_err()
            || stream.flush().is_err();
        if failed {
            cancel_and_drain(&ticket);
            return;
        }
        if matches!(event, Event::Done(_)) {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    }
}

/// Cancel a ticket and block until the engine finishes it: the returned
/// `Done` (or channel close) is the proof that the worker slot and every
/// KV block the request held are back in their pools.
fn cancel_and_drain(ticket: &Ticket) {
    ticket.cancel();
    loop {
        match ticket.recv() {
            Some(Event::Done(_)) | None => return,
            Some(_) => {}
        }
    }
}
