//! Speculative decoding: a cheap draft model proposes K tokens, the
//! target verifies all K+1 positions in **one** weight-stationary fused
//! batch step.
//!
//! With 1-bit packed weights decode is memory-bound on packed-plane reads
//! (the Appendix A regime), which is exactly the cost speculation
//! amortizes: the verify run is K+1 rows of a single
//! [`SeqStep`] through
//! [`PackedModel::decode_step_batch`], so the target reads each weight
//! column once for the whole run instead of once per token.
//!
//! Semantics:
//! * **Greedy** (`temperature <= 0`): a draft token is accepted iff it
//!   equals the target argmax at its position, and the first divergent
//!   position emits the target argmax instead.  Emitted tokens are
//!   therefore *bit-identical* to [`PackedModel::generate`] — speculation
//!   changes throughput, never output (property-tested in
//!   `tests/integration_spec.rs`).
//! * **Seeded sampling**: standard accept/resample — draft token `d ~ q`
//!   is accepted with probability `min(1, p(d)/q(d))`; a rejection draws
//!   the replacement from `norm(max(p - q, 0))`.  The emitted stream is
//!   distributed exactly as target-only sampling, and all randomness comes
//!   from the request's seeded [`Rng`], so runs are deterministic per
//!   (prompt, params, seed) regardless of batching.
//! * **Rollback**: the target feeds the whole run before acceptance is
//!   known, so rejected-suffix KV positions are truncated afterwards
//!   ([`PagedSeq::truncate`] returns whole blocks to the sequence's
//!   allowance; [`KvCache::truncate`] rewinds the write cursor).  Sequence
//!   length is non-monotonic under speculation — the KV layer, not the
//!   caller, owns making that safe.
//!
//! The serving engine integrates all of this into its fused round (see
//! `serve/engine.rs`: draft replicas are registry-leased per request,
//! draft KV pages from per-geometry pools, and verify runs share the batch
//! plan with plain decode rows and prefill chunks).  [`SpecDecoder`] is
//! the direct single-sequence driver — the reference implementation used
//! by `benches/spec_decode.rs`, `tests/alloc_free.rs`, and `repro eval
//! --draft-model`.

use std::sync::Arc;

use crate::infer::model::argmax;
use crate::infer::{BatchKv, KvCache, PackedModel, Scratch, SeqStep};
use crate::kvcache::{BlockPool, KvError, PagedSeq, PrefixTag};
use crate::util::rng::Rng;

use super::engine::SamplingParams;

/// Per-request speculative-decoding configuration, carried by
/// [`GenRequest`](super::GenRequest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecParams {
    /// Registry name of the draft model. Validated at submit time: the
    /// draft must exist and share the target's vocabulary (its depth and
    /// width are free — drafts page KV from their own per-geometry pool).
    pub draft: String,
    /// Max draft tokens proposed per verify round (the run is `k + 1`
    /// rows). Clamped to the remaining budget each round.
    pub k: usize,
}

impl SpecParams {
    pub fn new(draft: impl Into<String>, k: usize) -> SpecParams {
        SpecParams { draft: draft.into(), k }
    }
}

/// Cumulative speculative-decoding counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecStats {
    /// Draft tokens proposed across all verify runs.
    pub proposed: usize,
    /// Proposed tokens the target accepted.
    pub accepted: usize,
    /// Verify runs executed.
    pub verify_steps: usize,
    /// Draft-model fused decode steps executed.
    pub draft_steps: usize,
    /// Tokens emitted out of verify runs (accepted + correction/bonus).
    pub emitted: usize,
}

impl SpecStats {
    /// `accepted / proposed` (0 when nothing was proposed).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Mean emitted tokens per verify step — the net speedup knob (a
    /// plain decode step emits exactly 1).
    pub fn tokens_per_verify(&self) -> f64 {
        if self.verify_steps == 0 {
            0.0
        } else {
            self.emitted as f64 / self.verify_steps as f64
        }
    }

    /// Mean *accepted* draft tokens per verify step.
    pub fn accepted_per_verify(&self) -> f64 {
        if self.verify_steps == 0 {
            0.0
        } else {
            self.accepted as f64 / self.verify_steps as f64
        }
    }
}

// ------------------------------------------------------- sampled acceptance

/// Outcome of checking one drafted token against the target distribution.
pub(crate) enum DraftDraw {
    Accepted,
    /// Rejected; the replacement token drawn from `norm(max(p - q, 0))`.
    Rejected(u32),
}

/// Dense truncated-softmax distribution of `logits` under `p`
/// (temperature + top-k), written into `out` (`[vocab]`, zero outside the
/// candidate set). Candidate selection and the f64 softmax mirror the
/// engine's plain sampler, so speculation truncates exactly the
/// distribution plain sampling draws from.
pub(crate) fn dist_into(logits: &[f32], p: &SamplingParams, out: &mut [f32]) {
    debug_assert!(p.temperature > 0.0, "dense distributions are for sampled mode");
    debug_assert_eq!(logits.len(), out.len());
    let k = if p.top_k == 0 { logits.len() } else { p.top_k.min(logits.len()) };
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    out.fill(0.0);
    let mut total = 0f64;
    for &i in &idx {
        total += (((logits[i] - m) / p.temperature) as f64).exp();
    }
    for &i in &idx {
        out[i] = ((((logits[i] - m) / p.temperature) as f64).exp() / total) as f32;
    }
}

/// One draw from a dense probability row (exactly one RNG consumption).
pub(crate) fn sample_from(probs: &[f32], rng: &mut Rng) -> u32 {
    let total: f64 = probs.iter().map(|&x| x as f64).sum();
    let mut t = rng.f64() * total;
    let mut last = 0usize;
    for (i, &w) in probs.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        last = i;
        t -= w as f64;
        if t <= 0.0 {
            return i as u32;
        }
    }
    last as u32
}

/// Draft-side proposal: densify `q` from the draft logits and draw one
/// token from it (the `q` row is kept for the verify-time accept test).
pub(crate) fn propose_sampled(
    logits: &[f32],
    p: &SamplingParams,
    q_out: &mut [f32],
    rng: &mut Rng,
) -> u32 {
    dist_into(logits, p, q_out);
    sample_from(q_out, rng)
}

/// Target-side check of drafted token `d ~ q`: accept with probability
/// `min(1, p(d)/q(d))`, else draw the replacement from
/// `norm(max(p - q, 0))` — the residual construction that makes the
/// emitted stream distributed exactly as target-only sampling.
/// `p_scratch` holds the densified target distribution (reused per
/// request across rounds).
pub(crate) fn accept_draft(
    p_logits: &[f32],
    params: &SamplingParams,
    q: &[f32],
    d: u32,
    p_scratch: &mut Vec<f32>,
    rng: &mut Rng,
) -> DraftDraw {
    p_scratch.resize(p_logits.len(), 0.0);
    dist_into(p_logits, params, p_scratch);
    let pd = p_scratch[d as usize] as f64;
    let qd = q[d as usize] as f64;
    if qd > 0.0 && rng.f64() < (pd / qd).min(1.0) {
        return DraftDraw::Accepted;
    }
    let mut total = 0f64;
    for (pi, &qi) in p_scratch.iter_mut().zip(q) {
        *pi = (*pi - qi).max(0.0);
        total += *pi as f64;
    }
    if total <= 0.0 {
        // p == q (or numerically indistinguishable): the residual is
        // empty, so the replacement is a fresh draw from p itself.
        dist_into(p_logits, params, p_scratch);
    }
    DraftDraw::Rejected(sample_from(p_scratch, rng))
}

/// Bonus/correction draw straight from the target distribution (used
/// after the whole run was accepted, and by degenerate runs with no
/// proposals).
pub(crate) fn sample_dense(
    p_logits: &[f32],
    params: &SamplingParams,
    p_scratch: &mut Vec<f32>,
    rng: &mut Rng,
) -> u32 {
    p_scratch.resize(p_logits.len(), 0.0);
    dist_into(p_logits, params, p_scratch);
    sample_from(p_scratch, rng)
}

// ------------------------------------------------------------- SpecDecoder

/// Direct single-sequence greedy speculative decoder over two packed
/// models — the reference implementation of the draft → verify → rollback
/// round. The serving engine has its own batched integration; this driver
/// backs the bench, the allocation-freedom test, and `repro eval
/// --draft-model`.
///
/// All working state (scratch arena, run/catch-up buffers, KV) is owned
/// and reused, so once warm the steady-state round loop performs zero
/// heap allocations (verified in `tests/alloc_free.rs`).
pub struct SpecDecoder {
    k: usize,
    scratch: Scratch,
    /// Verify run `[pending, d_1..d_k_eff]`.
    run: Vec<u32>,
    /// Draft catch-up staging.
    ctx: Vec<u32>,
    out: Vec<u32>,
    target_contig: Vec<KvCache>,
    target_paged: Option<PagedSeq>,
    draft_kv: Vec<KvCache>,
    /// Positions fed into the target / the draft.
    pos: usize,
    dfed: usize,
    prompt_len: usize,
    n_new: usize,
    done: bool,
    pub stats: SpecStats,
}

impl SpecDecoder {
    /// A decoder proposing up to `k` draft tokens per round.
    pub fn new(k: usize) -> SpecDecoder {
        SpecDecoder {
            k: k.max(1),
            scratch: Scratch::new(),
            run: Vec::new(),
            ctx: Vec::new(),
            out: Vec::new(),
            target_contig: Vec::new(),
            target_paged: None,
            draft_kv: Vec::new(),
            pos: 0,
            dfed: 0,
            prompt_len: 0,
            n_new: 0,
            done: false,
            stats: SpecStats::default(),
        }
    }

    /// Prefill both models on `prompt` and emit the first token. With a
    /// pool the target's KV is paged (rollback returns whole blocks);
    /// contiguous otherwise. Stats accumulate across sessions — reset
    /// `self.stats` if you want per-session numbers.
    pub fn begin(
        &mut self,
        target: &mut PackedModel,
        draft: &mut PackedModel,
        prompt: &[u32],
        n_new: usize,
        pool: Option<&Arc<BlockPool>>,
    ) -> Result<(), KvError> {
        assert_eq!(
            target.cfg.vocab, draft.cfg.vocab,
            "draft and target must share a vocabulary"
        );
        self.out.clear();
        self.out.reserve(n_new);
        self.run.clear();
        self.ctx.clear();
        self.prompt_len = prompt.len();
        self.n_new = n_new;
        self.pos = 0;
        self.dfed = 0;
        self.done = n_new == 0;
        if self.done {
            return Ok(());
        }
        let worst = (prompt.len() + n_new.saturating_sub(1)).max(1);
        self.target_paged = None;
        match pool {
            Some(p) => {
                let adm = p.admit(&[], worst, PrefixTag::default())?;
                self.target_paged = Some(PagedSeq::new(p, adm));
            }
            None => self.ensure_contig_target(target, worst),
        }
        let dworst = prompt.len() + n_new + self.k;
        Self::ensure_caches(&mut self.draft_kv, draft, dworst);

        // Prefill: all prompt rows as one fused step per model.
        let mut first = 0u32; // empty prompt: argmax of zeroed logits
        if !prompt.is_empty() {
            {
                let kv = match self.target_paged.as_mut() {
                    Some(seq) => BatchKv::Paged(seq),
                    None => BatchKv::Contig(&mut self.target_contig[..]),
                };
                let mut steps = [SeqStep::new(prompt, 0, kv, true)];
                target.decode_step_batch(&mut steps, &mut self.scratch);
                assert!(steps[0].err.is_none(), "target prefill overflow");
            }
            first = argmax(self.scratch.logits_row(0)) as u32;
            let mut dsteps =
                [SeqStep::new(prompt, 0, BatchKv::Contig(&mut self.draft_kv[..]), false)];
            draft.decode_step_batch(&mut dsteps, &mut self.scratch);
            assert!(dsteps[0].err.is_none(), "draft prefill overflow");
            self.pos = prompt.len();
            self.dfed = prompt.len();
        }
        self.out.push(first);
        self.done = self.out.len() >= n_new;
        Ok(())
    }

    /// One draft → verify → rollback round; `false` once the budget is
    /// emitted.
    pub fn round(&mut self, target: &mut PackedModel, draft: &mut PackedModel) -> bool {
        if self.done {
            return false;
        }
        let remaining = self.n_new - self.out.len(); // >= 1
        let k_eff = self.k.min(remaining - 1);

        // Draft: catch up through the pending token (yields q_1), then
        // one single-row step per further proposal.
        self.ctx.clear();
        for i in self.dfed..self.pos + 1 {
            self.ctx.push(self.out[i - self.prompt_len]);
        }
        self.run.clear();
        self.run.push(*self.out.last().unwrap());
        for j in 0..k_eff {
            let tok = [if j == 0 { 0 } else { self.run[j] }];
            let next;
            {
                let toks: &[u32] = if j == 0 { &self.ctx } else { &tok };
                let start = self.dfed;
                let mut steps =
                    [SeqStep::new(toks, start, BatchKv::Contig(&mut self.draft_kv[..]), true)];
                draft.decode_step_batch(&mut steps, &mut self.scratch);
                assert!(steps[0].err.is_none(), "draft KV overflow");
                self.dfed += steps[0].tokens.len();
                next = argmax(self.scratch.logits_row(0)) as u32;
            }
            self.run.push(next);
            self.stats.draft_steps += 1;
        }
        // k_eff == 0 (one budget slot left) proposes nothing: the verify
        // run below is just the pending token, and the session ends on
        // its emission — no draft catch-up needed.
        self.stats.proposed += k_eff;

        // Verify: the whole run as K+1 rows of one fused step, logits for
        // every row.
        {
            let run = std::mem::take(&mut self.run);
            let kv = match self.target_paged.as_mut() {
                Some(seq) => BatchKv::Paged(seq),
                None => BatchKv::Contig(&mut self.target_contig[..]),
            };
            let mut steps = [SeqStep::with_all_logits(&run, self.pos, kv)];
            target.decode_step_batch(&mut steps, &mut self.scratch);
            assert!(steps[0].err.is_none(), "target verify overflow");
            drop(steps);
            self.run = run;
        }
        self.stats.verify_steps += 1;

        // Greedy acceptance scan: each accepted draft equals the target
        // argmax; the first divergence (or the bonus position) emits the
        // target argmax and ends the round.
        let mut accepted = 0usize;
        for i in 0..self.run.len() {
            let t = argmax(self.scratch.logits_row_at(0, i)) as u32;
            let acc = i + 1 < self.run.len() && t == self.run[i + 1];
            self.out.push(t);
            self.stats.emitted += 1;
            if acc {
                accepted += 1;
            }
            if self.out.len() >= self.n_new {
                self.done = true;
                break;
            }
            if !acc {
                break;
            }
        }
        self.stats.accepted += accepted;

        // Rollback: rejected-suffix positions leave both KVs.
        let new_pos = self.pos + 1 + accepted;
        match self.target_paged.as_mut() {
            Some(seq) => seq.truncate(new_pos),
            None => {
                for c in self.target_contig.iter_mut() {
                    c.truncate(new_pos);
                }
            }
        }
        self.pos = new_pos;
        let dlen = self.dfed.min(new_pos);
        for c in self.draft_kv.iter_mut() {
            c.truncate(dlen);
        }
        self.dfed = dlen;
        !self.done
    }

    /// Tokens emitted so far this session.
    pub fn tokens(&self) -> &[u32] {
        &self.out
    }

    /// Full greedy generation — bit-identical to
    /// [`PackedModel::generate`] on the target, whatever the draft.
    pub fn generate(
        &mut self,
        target: &mut PackedModel,
        draft: &mut PackedModel,
        prompt: &[u32],
        n_new: usize,
        pool: Option<&Arc<BlockPool>>,
    ) -> Vec<u32> {
        self.begin(target, draft, prompt, n_new, pool)
            .expect("KV admission for speculative session");
        while self.round(target, draft) {}
        self.out.clone()
    }

    fn ensure_contig_target(&mut self, model: &PackedModel, tokens: usize) {
        Self::ensure_caches(&mut self.target_contig, model, tokens);
    }

    /// Reuse per-layer caches across sessions, rebuilding only when the
    /// geometry or capacity no longer fits.
    fn ensure_caches(caches: &mut Vec<KvCache>, model: &PackedModel, tokens: usize) {
        let d = model.cfg.d_model;
        let fits = caches.len() == model.cfg.n_layers
            && caches.iter().all(|c| c.k.len() >= tokens * d);
        if fits {
            for c in caches.iter_mut() {
                c.reset();
            }
        } else {
            *caches = model.new_caches(tokens);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};
    use crate::kvcache::KvPoolOptions;

    fn cfg(seed_name: &str) -> ModelConfig {
        ModelConfig {
            name: seed_name.into(),
            variant: Variant::PQuant,
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 96,
            r: 16,
            n_experts: 2,
            seq_len: 64,
            alpha_init: 2.0,
            beta_init: 0.2,
        }
    }

    #[test]
    fn greedy_spec_decoder_matches_generate() {
        let mut target = PackedModel::random(&cfg("spec-t"), 7);
        let mut reference = target.clone();
        let mut draft = PackedModel::random(&cfg("spec-d"), 8);
        let want = reference.generate(&[3, 1, 4], 12);
        let mut dec = SpecDecoder::new(3);
        let got = dec.generate(&mut target, &mut draft, &[3, 1, 4], 12, None);
        assert_eq!(got, want, "speculation must never change greedy output");
        assert_eq!(dec.stats.emitted, 12);
        assert!(dec.stats.verify_steps > 0);
    }

    #[test]
    fn self_draft_accepts_every_proposal() {
        let mut target = PackedModel::random(&cfg("spec-self"), 9);
        let mut draft = target.clone();
        let mut reference = target.clone();
        let mut dec = SpecDecoder::new(4);
        let got = dec.generate(&mut target, &mut draft, &[5, 2], 16, None);
        assert_eq!(got, reference.generate(&[5, 2], 16));
        assert_eq!(dec.stats.accepted, dec.stats.proposed, "identical models must agree");
        assert!(dec.stats.acceptance_rate() == 1.0);
        // All-accepted rounds emit k+1 tokens per verify.
        assert!(dec.stats.tokens_per_verify() > 4.0);
    }

    #[test]
    fn paged_target_matches_contiguous() {
        let c = cfg("spec-paged");
        let mut target = PackedModel::random(&c, 11);
        let mut draft = PackedModel::random(&c, 12);
        let pool = Arc::new(BlockPool::new(
            KvPoolOptions { n_blocks: 64, block_size: 4, ..Default::default() },
            c.n_layers,
            c.d_model,
        ));
        let mut dec = SpecDecoder::new(3);
        let contig = dec.generate(&mut target, &mut draft, &[9, 9, 1], 10, None);
        let paged = dec.generate(&mut target, &mut draft, &[9, 9, 1], 10, Some(&pool));
        assert_eq!(contig, paged, "paged rollback must be bit-identical");
        drop(dec);
        assert_eq!(pool.available(), 64, "session end returns every block");
    }

    #[test]
    fn sampled_helpers_are_deterministic_and_normalized() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.61).sin()).collect();
        let p = SamplingParams { temperature: 0.7, top_k: 6, seed: 0, stop_tokens: vec![] };
        let mut q = vec![0.0f32; 32];
        dist_into(&logits, &p, &mut q);
        let total: f64 = q.iter().map(|&x| x as f64).sum();
        assert!((total - 1.0).abs() < 1e-5, "q must be a distribution, got {total}");
        assert_eq!(q.iter().filter(|&&x| x > 0.0).count(), 6, "top-k support");
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..40).map(|_| sample_from(&q, &mut rng)).collect::<Vec<u32>>()
        };
        assert_eq!(draw(5), draw(5));
        assert!(draw(5).iter().all(|&t| q[t as usize] > 0.0));
    }

    #[test]
    fn rejection_resamples_from_the_residual() {
        // q concentrated where p is light: the accept test must sometimes
        // reject, and every replacement must come from p's support.
        let mut rng = Rng::new(3);
        let p_logits: Vec<f32> = (0..16).map(|i| if i < 4 { 3.0 } else { -3.0 }).collect();
        let q_logits: Vec<f32> = (0..16).map(|i| if i >= 12 { 3.0 } else { -3.0 }).collect();
        let params = SamplingParams { temperature: 1.0, top_k: 0, seed: 0, stop_tokens: vec![] };
        let mut q = vec![0.0f32; 16];
        dist_into(&q_logits, &params, &mut q);
        let mut scratch = Vec::new();
        let mut rejections = 0;
        for _ in 0..50 {
            let d = sample_from(&q, &mut rng);
            match accept_draft(&p_logits, &params, &q, d, &mut scratch, &mut rng) {
                DraftDraw::Accepted => {}
                DraftDraw::Rejected(t) => {
                    rejections += 1;
                    assert!(t < 4, "replacement {t} must come from p-heavy support");
                }
            }
        }
        assert!(rejections > 30, "mismatched q must mostly reject, got {rejections}");
    }
}
