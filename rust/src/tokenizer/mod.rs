//! Byte-level BPE tokenizer (paper Appendix B: "BPE tokenizer with a
//! vocabulary size of 32K" — scaled here to the config's vocab).
//!
//! Training: start from the 256 byte tokens, repeatedly merge the most
//! frequent adjacent pair until `vocab_size` tokens exist.  Encoding:
//! greedy lowest-rank merge application (the canonical BPE inference).
//! Vocabularies persist as JSON next to checkpoints.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::util::json::{arr, num, obj, Json};

/// A trained BPE vocabulary: token id ↔ byte sequence, plus merge ranks.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// token id → bytes. ids 0..256 are the raw bytes.
    pub tokens: Vec<Vec<u8>>,
    /// (left id, right id) → merged id, insertion order = rank.
    pub merges: Vec<(u32, u32, u32)>,
    merge_rank: HashMap<(u32, u32), (u32, u32)>, // pair → (rank, merged id)
}

impl Bpe {
    /// Train on `text` until the vocabulary holds `vocab_size` tokens.
    pub fn train(text: &str, vocab_size: usize) -> Bpe {
        assert!(vocab_size >= 256, "vocab must include the byte alphabet");
        let mut tokens: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = Vec::new();

        // Work on word chunks (split on whitespace, keep a leading space
        // marker) so merges never cross word boundaries — the standard
        // GPT-2-style pre-tokenization, which keeps encode() fast.
        let mut words: HashMap<Vec<u32>, usize> = HashMap::new();
        for word in text.split_inclusive(char::is_whitespace) {
            let ids: Vec<u32> = word.bytes().map(|b| b as u32).collect();
            if !ids.is_empty() {
                *words.entry(ids).or_insert(0) += 1;
            }
        }

        while tokens.len() < vocab_size {
            // Count adjacent pairs across the word multiset.
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (word, &count) in &words {
                for w in word.windows(2) {
                    *pair_counts.entry((w[0], w[1])).or_insert(0) += count;
                }
            }
            // Deterministic argmax: highest count, ties broken by pair id.
            let Some((&pair, &count)) = pair_counts
                .iter()
                .max_by_key(|(&(a, b), &c)| (c, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if count < 2 {
                break; // nothing left worth merging
            }
            let new_id = tokens.len() as u32;
            let mut merged_bytes = tokens[pair.0 as usize].clone();
            merged_bytes.extend_from_slice(&tokens[pair.1 as usize]);
            tokens.push(merged_bytes);
            merges.push((pair.0, pair.1, new_id));

            // Apply the merge to every word.
            let mut next: HashMap<Vec<u32>, usize> = HashMap::with_capacity(words.len());
            for (word, count) in words.drain() {
                let merged = apply_merge(&word, pair, new_id);
                *next.entry(merged).or_insert(0) += count;
            }
            words = next;
        }

        let mut bpe = Bpe { tokens, merges, merge_rank: HashMap::new() };
        bpe.rebuild_rank();
        bpe
    }

    fn rebuild_rank(&mut self) {
        self.merge_rank = self
            .merges
            .iter()
            .enumerate()
            .map(|(rank, &(a, b, id))| ((a, b), (rank as u32, id)))
            .collect();
    }

    pub fn vocab_size(&self) -> usize {
        self.tokens.len()
    }

    /// Encode text to token ids (greedy lowest-rank merging per word).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 3 + 1);
        for word in text.split_inclusive(char::is_whitespace) {
            let mut ids: Vec<u32> = word.bytes().map(|b| b as u32).collect();
            loop {
                // find the lowest-rank applicable merge
                let mut best: Option<(u32, usize, u32)> = None; // (rank, pos, id)
                for (pos, w) in ids.windows(2).enumerate() {
                    if let Some(&(rank, id)) = self.merge_rank.get(&(w[0], w[1])) {
                        if best.is_none() || rank < best.unwrap().0 {
                            best = Some((rank, pos, id));
                        }
                    }
                }
                match best {
                    Some((_, pos, id)) => {
                        ids.splice(pos..pos + 2, [id]);
                    }
                    None => break,
                }
            }
            out.extend_from_slice(&ids);
        }
        out
    }

    /// Decode token ids back to text (lossy only on invalid UTF-8).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            bytes.extend_from_slice(&self.tokens[id as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Persist as JSON (merges only — tokens are reconstructable).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("vocab_size", num(self.tokens.len() as f64)),
            (
                "merges",
                arr(self.merges.iter().map(|&(a, b, id)| {
                    arr([num(a as f64), num(b as f64), num(id as f64)])
                })),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Bpe> {
        let vocab_size = j.get("vocab_size")?.as_usize()?;
        let mut tokens: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = Vec::new();
        for m in j.get("merges")?.as_arr()? {
            let m = m.as_arr()?;
            if m.len() != 3 {
                bail!("bad merge entry");
            }
            let (a, b, id) =
                (m[0].as_usize()? as u32, m[1].as_usize()? as u32, m[2].as_usize()? as u32);
            if id as usize != tokens.len() {
                bail!("merge ids out of order");
            }
            let mut bytes = tokens[a as usize].clone();
            bytes.extend_from_slice(&tokens[b as usize]);
            tokens.push(bytes);
            merges.push((a, b, id));
        }
        if tokens.len() != vocab_size {
            bail!("vocab size mismatch: {} vs {}", tokens.len(), vocab_size);
        }
        let mut bpe = Bpe { tokens, merges, merge_rank: HashMap::new() };
        bpe.rebuild_rank();
        Ok(bpe)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<Bpe> {
        let text = std::fs::read_to_string(path)?;
        Bpe::from_json(&Json::parse(&text)?)
    }
}

fn apply_merge(word: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(word.len());
    let mut i = 0;
    while i < word.len() {
        if i + 1 < word.len() && word[i] == pair.0 && word[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(word[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the quick brown fox jumps over the lazy dog \
        the quick brown fox jumps again and again the fox is quick ";

    #[test]
    fn roundtrip_exact() {
        let bpe = Bpe::train(SAMPLE, 300);
        for text in [SAMPLE, "the fox", "unseen words zxqj", "a"] {
            assert_eq!(bpe.decode(&bpe.encode(text)), text);
        }
    }

    #[test]
    fn compression_happens() {
        let bpe = Bpe::train(SAMPLE, 320);
        let ids = bpe.encode("the quick brown fox");
        assert!(ids.len() < "the quick brown fox".len(), "no compression: {ids:?}");
    }

    #[test]
    fn byte_fallback_for_unseen() {
        let bpe = Bpe::train(SAMPLE, 280);
        let ids = bpe.encode("€"); // multi-byte, unseen
        assert_eq!(bpe.decode(&ids), "€");
    }

    #[test]
    fn json_roundtrip() {
        let bpe = Bpe::train(SAMPLE, 300);
        let j = bpe.to_json();
        let loaded = Bpe::from_json(&j).unwrap();
        assert_eq!(loaded.tokens, bpe.tokens);
        assert_eq!(loaded.encode(SAMPLE), bpe.encode(SAMPLE));
    }

    #[test]
    fn ids_below_vocab() {
        let bpe = Bpe::train(SAMPLE, 300);
        for &id in &bpe.encode(SAMPLE) {
            assert!((id as usize) < bpe.vocab_size());
        }
    }

    #[test]
    fn deterministic_training() {
        let a = Bpe::train(SAMPLE, 300);
        let b = Bpe::train(SAMPLE, 300);
        assert_eq!(a.merges, b.merges);
    }
}
