//! Model/variant configurations — the rust mirror of
//! `python/compile/configs.py` (kept in sync by an integration test that
//! cross-checks against `artifacts/*/manifest.json`).
//!
//! Also carries the *paper-scale* configs (Table 1 / Table 4) used by the
//! analytic memory model and the Figure-8 workload shapes, which never run
//! through PJRT.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Quantization variant of a model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Full-precision baseline (f32 on this testbed; "FP16" in the paper).
    Fp16,
    /// BitNet: every linear 1-bit sign/absmean, W1A8.
    BitNet,
    /// BitNet1.58: every linear ternary absmean, W1.58A8.
    BitNet158,
    /// pQuant: 1-bit MHA + decoupled FFN with N INT8 expert branches.
    PQuant,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        Ok(match s {
            "fp16" => Variant::Fp16,
            "bitnet" => Variant::BitNet,
            "bitnet158" => Variant::BitNet158,
            "pquant" => Variant::PQuant,
            _ => bail!("unknown variant {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Fp16 => "fp16",
            Variant::BitNet => "bitnet",
            Variant::BitNet158 => "bitnet158",
            Variant::PQuant => "pquant",
        }
    }

    /// Storage bits per weight in quantized linear layers.
    pub fn weight_bits(&self) -> f64 {
        match self {
            Variant::Fp16 => 16.0,
            Variant::BitNet => 1.0,
            Variant::BitNet158 => 1.58,
            Variant::PQuant => 1.0, // 1-bit branch; the 8-bit branch is counted separately
        }
    }
}

/// One (size, variant) model configuration. Field semantics match
/// `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub variant: Variant,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub r: usize,
    pub n_experts: usize,
    pub seq_len: usize,
    pub alpha_init: f32,
    pub beta_init: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn d_ff_1bit(&self) -> usize {
        self.d_ff - self.r
    }

    /// Total parameter count (embeddings + blocks + head); mirrors the
    /// python `param_count` exactly (cross-checked in tests).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let v = self.vocab;
        let mut n = 2 * v * d;
        let mut per_layer = 4 * d * d + 2 * d;
        if self.variant == Variant::PQuant {
            per_layer += 2 * d * self.d_ff_1bit();
            per_layer += self.n_experts * 2 * d * self.r;
            per_layer += d * self.n_experts;
            per_layer += 2;
        } else {
            per_layer += 2 * d * self.d_ff;
        }
        n += self.n_layers * per_layer;
        n + d
    }

    /// Parameters touched per forward pass (top-1 routing: one expert).
    pub fn activated_param_count(&self) -> usize {
        if self.variant != Variant::PQuant {
            return self.param_count();
        }
        self.param_count()
            - (self.n_experts - 1) * 2 * self.d_model * self.r * self.n_layers
    }

    /// Average storage bits per block weight (paper's 1.28-1.35 bit).
    pub fn avg_bits_per_weight(&self) -> f64 {
        let d = self.d_model as f64;
        match self.variant {
            Variant::Fp16 => 16.0,
            Variant::BitNet => 1.0,
            Variant::BitNet158 => 1.58,
            Variant::PQuant => {
                let one = 4.0 * d * d + 2.0 * d * self.d_ff_1bit() as f64;
                let eight = self.n_experts as f64 * 2.0 * d * self.r as f64;
                (one + eight * 8.0) / (one + eight)
            }
        }
    }

    /// Parse the `config` object embedded in an artifact manifest.
    pub fn from_manifest_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            variant: Variant::parse(j.get("variant")?.as_str()?)?,
            vocab: j.get("vocab")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            r: j.get("r")?.as_usize()?,
            n_experts: j.get("n_experts")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            alpha_init: j.get("alpha_init")?.as_f64()? as f32,
            beta_init: j.get("beta_init")?.as_f64()? as f32,
        })
    }
}

/// Paper-scale configurations (Table 1 for pQuant, Table 4 for baselines).
/// Used by the analytic memory model (Fig 6, Tables 3/6) and the Figure-8
/// workload shapes; these sizes never execute on this testbed.
pub fn paper_configs() -> Vec<ModelConfig> {
    let mk = |name: &str, variant, d_model, n_layers, n_heads, d_ff, r, n_experts| ModelConfig {
        name: name.to_string(),
        variant,
        vocab: 32_000, // paper: BPE tokenizer, 32K vocab (Appendix B)
        d_model,
        n_layers,
        n_heads,
        d_ff,
        r,
        n_experts,
        seq_len: 2048,
        alpha_init: 2.0,
        beta_init: 0.2,
    };
    vec![
        // pQuant, paper Table 1: D_FF column is "total(base - r)"
        mk("paper-300M-pquant", Variant::PQuant, 1024, 24, 16, 2400, 128, 1),
        mk("paper-700M-pquant", Variant::PQuant, 1536, 24, 24, 4096, 256, 1),
        mk("paper-1.3B-pquant", Variant::PQuant, 2048, 24, 32, 5460, 384, 1),
        mk("paper-2.6B-pquant", Variant::PQuant, 2880, 24, 32, 7680, 512, 1),
        // Baselines, paper Table 4
        mk("paper-300M-fp16", Variant::Fp16, 1024, 24, 16, 2400, 0, 1),
        mk("paper-700M-fp16", Variant::Fp16, 1536, 24, 24, 4096, 0, 1),
        mk("paper-1.3B-fp16", Variant::Fp16, 2048, 24, 32, 5460, 0, 1),
        mk("paper-300M-bitnet", Variant::BitNet, 1024, 24, 16, 2400, 0, 1),
        mk("paper-700M-bitnet", Variant::BitNet, 1536, 24, 24, 4096, 0, 1),
        mk("paper-1.3B-bitnet", Variant::BitNet, 2048, 24, 32, 5460, 0, 1),
        mk("paper-300M-bitnet158", Variant::BitNet158, 1024, 24, 16, 2400, 0, 1),
        mk("paper-700M-bitnet158", Variant::BitNet158, 1536, 24, 24, 4096, 0, 1),
        mk("paper-1.3B-bitnet158", Variant::BitNet158, 2048, 24, 32, 5460, 0, 1),
        // 7B LLaMA-2 shape for the Figure-8 component-time workload
        mk("paper-7B-fp16", Variant::Fp16, 4096, 32, 32, 11008, 0, 1),
        mk("paper-7B-bitnet158", Variant::BitNet158, 4096, 32, 32, 11008, 0, 1),
        mk("paper-7B-pquant", Variant::PQuant, 4096, 32, 32, 11008, 512, 1),
    ]
}

/// Tiny CPU-friendly config for CI smoke runs: big enough to exercise
/// every serving path (paged KV, prefill chunks, fused batching), small
/// enough that `repro export smoke --random` + a short loadtest finish in
/// seconds on one core.
pub fn smoke_config() -> ModelConfig {
    ModelConfig {
        name: "smoke".to_string(),
        variant: Variant::PQuant,
        vocab: 512,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        d_ff: 176,
        r: 16,
        n_experts: 1,
        seq_len: 256,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

/// Paper-scale pQuant config with N experts (for Table 6 / Fig 6 sweeps).
pub fn paper_pquant_n(base: &ModelConfig, n_experts: usize) -> ModelConfig {
    let mut c = base.clone();
    c.n_experts = n_experts;
    c.name = format!("{}-n{n_experts}", base.name);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_pquant() -> ModelConfig {
        ModelConfig {
            name: "tiny-pquant".into(),
            variant: Variant::PQuant,
            vocab: 1024,
            d_model: 256,
            n_layers: 4,
            n_heads: 8,
            d_ff: 704,
            r: 32,
            n_experts: 1,
            seq_len: 128,
            alpha_init: 2.0,
            beta_init: 0.2,
        }
    }

    #[test]
    fn param_count_breakdown() {
        let c = tiny_pquant();
        // manual: 2*1024*256 embed/head + 256 final norm
        //  + 4 layers * (4*256*256 + 2*256 + 2*256*672 + 2*256*32 + 256 + 2)
        let per_layer = 4 * 256 * 256 + 2 * 256 + 2 * 256 * 672 + 2 * 256 * 32 + 256 + 2;
        assert_eq!(c.param_count(), 2 * 1024 * 256 + 256 + 4 * per_layer);
    }

    #[test]
    fn activated_equals_total_when_single_expert() {
        let c = tiny_pquant();
        assert_eq!(c.param_count(), c.activated_param_count());
        let mut c8 = c.clone();
        c8.n_experts = 8;
        assert!(c8.activated_param_count() < c8.param_count());
        assert_eq!(
            c8.param_count() - c8.activated_param_count(),
            7 * 2 * 256 * 32 * 4
        );
    }

    #[test]
    fn avg_bits_in_paper_range() {
        // Paper reports 1.28-1.35 bits for its configs; ours keep the ratio.
        let c = tiny_pquant();
        let bits = c.avg_bits_per_weight();
        assert!(bits > 1.05 && bits < 1.6, "bits = {bits}");
    }

    #[test]
    fn paper_configs_have_sane_sizes() {
        for c in paper_configs() {
            let p = c.param_count() as f64;
            match &c.name {
                n if n.contains("300M") => assert!((1e8..6e8).contains(&p), "{n}: {p}"),
                n if n.contains("700M") => assert!((4e8..1.2e9).contains(&p), "{n}: {p}"),
                n if n.contains("1.3B") => assert!((0.9e9..2.0e9).contains(&p), "{n}: {p}"),
                n if n.contains("2.6B") => assert!((1.8e9..3.6e9).contains(&p), "{n}: {p}"),
                n if n.contains("7B") => assert!((5e9..9e9).contains(&p), "{n}: {p}"),
                n => panic!("unclassified config {n}"),
            }
        }
    }

    #[test]
    fn variant_roundtrip() {
        for v in [Variant::Fp16, Variant::BitNet, Variant::BitNet158, Variant::PQuant] {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
        }
        assert!(Variant::parse("int4").is_err());
    }
}
