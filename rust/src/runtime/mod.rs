//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute them.
//!
//! The interchange contract with `python/compile/aot.py`:
//!   * one directory per config under `artifacts/`
//!   * `manifest.json` describes every executable's operand/result layout
//!     in *flat pytree order* (sorted dict keys, list index order)
//!   * `*.hlo.txt` are HLO-text modules lowered with `return_tuple=True`,
//!     so every execution returns a single tuple literal that is
//!     decomposed positionally
//!   * `init.npz` holds the seeded initial parameters by flat name
//!
//! Python never runs at runtime — after `make artifacts` the rust binary is
//! self-contained.

pub mod state;

pub use state::TrainState;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use xla::FromRawBytes;

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Tensor dtype in manifests (the only two the models use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "s32" => Dtype::S32,
            _ => bail!("unknown dtype {s:?}"),
        })
    }
}

/// Shape+dtype of one operand/result.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered function (train_step, fwd, ...) described by the manifest.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub key: String,
    pub file: String,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest of one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelConfig,
    pub seed: u64,
    pub train_batch: usize,
    pub seq_len: usize,
    pub param_layout: Vec<TensorSpec>,
    pub entries: HashMap<String, EntrySpec>,
    pub param_count: usize,
    pub activated_param_count: usize,
    pub avg_bits_per_weight: f64,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let config = ModelConfig::from_manifest_json(j.get("config")?)?;
        let derived = j.get("derived")?;
        let param_layout = j
            .get("param_layout")?
            .as_arr()?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut entries = HashMap::new();
        for (key, e) in j.get("entries")?.as_obj()? {
            entries.insert(
                key.clone(),
                EntrySpec {
                    key: key.clone(),
                    file: e.get("file")?.as_str()?.to_string(),
                    batch: e.get("batch")?.as_usize()?,
                    inputs: e
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: e
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }
        Ok(Manifest {
            config,
            seed: j.get("seed")?.as_f64()? as u64,
            train_batch: j.get("train_batch")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            param_layout,
            entries,
            param_count: derived.get("param_count")?.as_usize()?,
            activated_param_count: derived.get("activated_param_count")?.as_usize()?,
            avg_bits_per_weight: derived.get("avg_bits_per_weight")?.as_f64()?,
        })
    }
}

/// An artifact directory on disk (not yet compiled).
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Artifact {
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifact> {
        let dir = dir.as_ref().to_path_buf();
        let mtext = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        Ok(Artifact { dir, manifest: Manifest::parse(&mtext)? })
    }

    /// Load the seeded initial parameters from init.npz, ordered to match
    /// `manifest.param_layout`.
    pub fn initial_params(&self) -> Result<Vec<xla::Literal>> {
        let named = xla::Literal::read_npz(self.dir.join("init.npz"), &())?;
        let by_name: HashMap<String, xla::Literal> = named.into_iter().collect();
        self.manifest
            .param_layout
            .iter()
            .map(|spec| {
                by_name
                    .get(&spec.name)
                    .map(clone_literal)
                    .ok_or_else(|| anyhow!("init.npz missing {}", spec.name))?
            })
            .collect()
    }

    /// Parse golden.json if present (nano configs).
    pub fn golden(&self) -> Result<Option<Golden>> {
        let path = self.dir.join("golden.json");
        if !path.exists() {
            return Ok(None);
        }
        let j = Json::parse(&std::fs::read_to_string(path)?)?;
        let tokens: Vec<i32> = j
            .get("tokens")?
            .as_arr()?
            .iter()
            .flat_map(|row| row.as_arr().unwrap().iter())
            .map(|v| v.as_f64().map(|f| f as i32))
            .collect::<Result<_>>()?;
        Ok(Some(Golden {
            tokens,
            lr: j.get("sched_lr")?.as_f64()? as f32,
            wd: j.get("sched_wd")?.as_f64()? as f32,
            losses: j
                .get("losses")?
                .as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|f| f as f32))
                .collect::<Result<_>>()?,
        }))
    }
}

/// Recorded python-side loss trajectory (ground truth for integration tests).
#[derive(Debug, Clone)]
pub struct Golden {
    pub tokens: Vec<i32>,
    pub lr: f32,
    pub wd: f32,
    pub losses: Vec<f32>,
}

/// Literal has no Clone in the xla crate; round-trip through raw bytes.
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let mut bytes = vec![0u8; l.size_bytes()];
    match l.ty()? {
        xla::ElementType::F32 => {
            let mut v = vec![0f32; l.element_count()];
            l.copy_raw_to(&mut v)?;
            bytes.copy_from_slice(unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            });
        }
        xla::ElementType::S32 => {
            let mut v = vec![0i32; l.element_count()];
            l.copy_raw_to(&mut v)?;
            bytes.copy_from_slice(unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            });
        }
        t => bail!("unsupported literal type {t:?}"),
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        l.ty()?, &dims, &bytes,
    )?)
}

/// Build an f32 literal with the given dims.
pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(n, data.len(), "literal_f32 shape/data mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Build an i32 literal with the given dims.
pub fn literal_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    assert_eq!(n, data.len(), "literal_i32 shape/data mismatch");
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Zero-filled f32 literal.
pub fn literal_zeros(spec: &TensorSpec) -> Result<xla::Literal> {
    match spec.dtype {
        Dtype::F32 => literal_f32(&spec.shape, &vec![0.0; spec.element_count()]),
        Dtype::S32 => literal_i32(&spec.shape, &vec![0; spec.element_count()]),
    }
}

/// f32 contents of a literal.
pub fn literal_to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    Ok(l.to_vec::<f32>()?)
}

/// The PJRT runtime: a CPU client plus a compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) one entry of an artifact.
    pub fn compile(&self, art: &Artifact, entry: &str) -> Result<CompiledEntry> {
        let spec = art
            .manifest
            .entries
            .get(entry)
            .ok_or_else(|| anyhow!("artifact {:?} has no entry {entry:?}", art.dir))?
            .clone();
        let key = format!("{}::{entry}", art.dir.display());
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&key) {
                return Ok(CompiledEntry { exe: exe.clone(), spec });
            }
        }
        let path = art.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(CompiledEntry { exe, spec })
    }
}

/// A compiled executable plus its manifest layout.
pub struct CompiledEntry {
    pub exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub spec: EntrySpec,
}

impl CompiledEntry {
    /// Execute with positional literals; returns the decomposed result
    /// tuple (aot.py lowers with return_tuple=True → single tuple output).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "entry {} expects {} operands, got {}",
                self.spec.key,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "entry {} returned {} results, manifest says {}",
                self.spec.key,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        Ok(parts)
    }
}

/// Find the artifacts root: $PQUANT_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("PQUANT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Convenience: load an artifact by config name from the default root.
pub fn load_artifact(name: &str) -> Result<Artifact> {
    Artifact::load(artifacts_root().join(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "config": {"name": "nano-pquant", "variant": "pquant", "vocab": 512,
        "d_model": 64, "n_layers": 2, "n_heads": 2, "d_ff": 176, "r": 16,
        "n_experts": 1, "seq_len": 64, "alpha_init": 2.0, "beta_init": 0.2},
      "derived": {"param_count": 100, "activated_param_count": 100,
        "avg_bits_per_weight": 1.3, "d_ff_1bit": 160, "head_dim": 32},
      "seed": 1, "train_batch": 8, "seq_len": 64,
      "param_layout": [
        {"name": "final_norm", "shape": [64], "dtype": "f32"},
        {"name": "layers.0.alpha", "shape": [], "dtype": "f32"}
      ],
      "entries": {
        "fwd": {"file": "fwd.hlo.txt", "batch": 1,
          "inputs": [{"name": "tokens", "shape": [1, 64], "dtype": "s32"}],
          "outputs": [{"name": "logits", "shape": [1, 64, 512], "dtype": "f32"}]}
      }
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.config.d_model, 64);
        assert_eq!(m.param_layout.len(), 2);
        assert_eq!(m.param_layout[1].shape, Vec::<usize>::new());
        assert_eq!(m.entries["fwd"].outputs[0].shape, vec![1, 64, 512]);
    }

    #[test]
    fn manifest_rejects_bad_dtype() {
        let bad = MANIFEST.replace("\"s32\"", "\"s64\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn literal_helpers() {
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(literal_to_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        let z = literal_zeros(&TensorSpec {
            name: "z".into(),
            shape: vec![4],
            dtype: Dtype::F32,
        })
        .unwrap();
        assert_eq!(literal_to_f32(&z).unwrap(), vec![0.0; 4]);
    }

    #[test]
    fn scalar_literal() {
        let l = literal_f32(&[], &[7.5]).unwrap();
        assert_eq!(l.element_count(), 1);
        assert_eq!(literal_to_f32(&l).unwrap(), vec![7.5]);
    }
}
