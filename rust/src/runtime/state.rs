//! Host-resident training state threaded through the AOT train step.
//!
//! The train step is a pure function; the coordinator owns (params, m, v)
//! as host literals and swaps them wholesale after each execution.  The
//! sched operand [step, lr, wd] carries the two-phase schedule values.

use anyhow::{bail, Result};

use super::{literal_f32, literal_i32, literal_to_f32, literal_zeros, Artifact, CompiledEntry};

/// Parameters + Adam moments, positionally ordered per the manifest.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    /// 1-based Adam step counter (bias correction needs step ≥ 1).
    pub step: u64,
}

impl TrainState {
    /// Fresh state: seeded params from init.npz, zero moments.
    pub fn initial(art: &Artifact) -> Result<TrainState> {
        let params = art.initial_params()?;
        let m = art
            .manifest
            .param_layout
            .iter()
            .map(literal_zeros)
            .collect::<Result<Vec<_>>>()?;
        let v = art
            .manifest
            .param_layout
            .iter()
            .map(literal_zeros)
            .collect::<Result<Vec<_>>>()?;
        Ok(TrainState { params, m, v, step: 0 })
    }

    /// Run one train step; updates state in place and returns the loss.
    pub fn step(
        &mut self,
        entry: &CompiledEntry,
        tokens: &[i32],
        lr: f32,
        wd: f32,
    ) -> Result<f32> {
        let n = self.params.len();
        let expected = 3 * n + 2;
        if entry.spec.inputs.len() != expected {
            bail!(
                "train entry {} expects {} operands but state provides {expected}",
                entry.spec.key,
                entry.spec.inputs.len()
            );
        }
        let tok_spec = &entry.spec.inputs[expected - 1];
        if tok_spec.element_count() != tokens.len() {
            bail!(
                "token batch has {} elements, entry wants {:?}",
                tokens.len(),
                tok_spec.shape
            );
        }
        self.step += 1;
        let sched = literal_f32(&[3], &[self.step as f32, lr, wd])?;
        let tok = literal_i32(&tok_spec.shape, tokens)?;

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(expected);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.push(&sched);
        inputs.push(&tok);

        // CompiledEntry::run takes owned-slice positions; borrow via the
        // Borrow<Literal> bound on execute.
        let result = entry.exe.execute::<&xla::Literal>(&inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut parts = tuple.to_tuple()?;
        if parts.len() != 1 + 3 * n {
            bail!("train step returned {} results, want {}", parts.len(), 1 + 3 * n);
        }
        let loss = literal_to_f32(&parts[0])?[0];
        // Swap in the new state (drain preserves order).
        let mut it = parts.drain(1..);
        self.params = (&mut it).take(n).collect();
        self.m = (&mut it).take(n).collect();
        self.v = (&mut it).take(n).collect();
        Ok(loss)
    }

    /// Run the fwd entry against current params; returns (logits, ffn_input).
    pub fn forward(
        &self,
        entry: &CompiledEntry,
        tokens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.params.len();
        if entry.spec.inputs.len() != n + 1 {
            bail!(
                "fwd entry {} expects {} operands, state provides {}",
                entry.spec.key,
                entry.spec.inputs.len(),
                n + 1
            );
        }
        let tok_spec = &entry.spec.inputs[n];
        if tok_spec.element_count() != tokens.len() {
            bail!("token count {} != fwd spec {:?}", tokens.len(), tok_spec.shape);
        }
        let tok = literal_i32(&tok_spec.shape, tokens)?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tok);
        let result = entry.exe.execute::<&xla::Literal>(&inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 2 {
            bail!("fwd returned {} results, want 2", parts.len());
        }
        Ok((literal_to_f32(&parts[0])?, literal_to_f32(&parts[1])?))
    }

    /// Fetch one parameter tensor by manifest name.
    pub fn param_by_name(&self, art: &Artifact, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        for (spec, lit) in art.manifest.param_layout.iter().zip(&self.params) {
            if spec.name == name {
                return Ok((spec.shape.clone(), literal_to_f32(lit)?));
            }
        }
        bail!("no parameter named {name:?}")
    }

    /// Persist (params, m, v, step) as a checkpoint.
    ///
    /// Format "PQCK1" (the vendored xla crate's npz *writer* mis-declares
    /// element types, so checkpoints use a self-contained binary layout):
    /// header magic, step u64, entry count u32, then per entry:
    /// name_len u32 + name bytes + rank u32 + dims u64* + f32 data.
    pub fn save_checkpoint(&self, art: &Artifact, path: &str) -> Result<()> {
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(b"PQCK1\0");
        out.extend_from_slice(&self.step.to_le_bytes());
        let n_entries = (self.params.len() * 3) as u32;
        out.extend_from_slice(&n_entries.to_le_bytes());
        let mut push = |name: String, lit: &xla::Literal| -> Result<()> {
            let data = literal_to_f32(lit)?;
            let spec_dims: Vec<u64> = lit
                .array_shape()?
                .dims()
                .iter()
                .map(|&d| d as u64)
                .collect();
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(spec_dims.len() as u32).to_le_bytes());
            for d in &spec_dims {
                out.extend_from_slice(&d.to_le_bytes());
            }
            for x in &data {
                out.extend_from_slice(&x.to_le_bytes());
            }
            Ok(())
        };
        for (spec, lit) in art.manifest.param_layout.iter().zip(&self.params) {
            push(format!("p.{}", spec.name), lit)?;
        }
        for (spec, lit) in art.manifest.param_layout.iter().zip(&self.m) {
            push(format!("m.{}", spec.name), lit)?;
        }
        for (spec, lit) in art.manifest.param_layout.iter().zip(&self.v) {
            push(format!("v.{}", spec.name), lit)?;
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Restore a checkpoint written by [`save_checkpoint`].
    pub fn load_checkpoint(art: &Artifact, path: &str) -> Result<TrainState> {
        use std::collections::HashMap;
        let bytes = std::fs::read(path)?;
        let mut r = Reader { b: &bytes, i: 0 };
        if r.take(6)? != b"PQCK1\0" {
            bail!("not a PQCK1 checkpoint: {path}");
        }
        let step = u64::from_le_bytes(r.take(8)?.try_into().unwrap());
        let n_entries = u32::from_le_bytes(r.take(4)?.try_into().unwrap()) as usize;
        let mut by_name: HashMap<String, xla::Literal> = HashMap::new();
        for _ in 0..n_entries {
            let name_len = u32::from_le_bytes(r.take(4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())?;
            let rank = u32::from_le_bytes(r.take(4)?.try_into().unwrap()) as usize;
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(u64::from_le_bytes(r.take(8)?.try_into().unwrap()) as usize);
            }
            let count: usize = dims.iter().product();
            let raw = r.take(count * 4)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            by_name.insert(name, literal_f32(&dims, &data)?);
        }
        let mut take_lit = |prefix: &str, name: &str| -> Result<xla::Literal> {
            by_name
                .remove(&format!("{prefix}.{name}"))
                .ok_or_else(|| anyhow::anyhow!("checkpoint missing {prefix}.{name}"))
        };
        let mut params = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for spec in &art.manifest.param_layout {
            params.push(take_lit("p", &spec.name)?);
        }
        for spec in &art.manifest.param_layout {
            m.push(take_lit("m", &spec.name)?);
        }
        for spec in &art.manifest.param_layout {
            v.push(take_lit("v", &spec.name)?);
        }
        Ok(TrainState { params, m, v, step })
    }
}

/// Bounds-checked byte cursor for the checkpoint reader.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated checkpoint (wanted {n} bytes at offset {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
}
