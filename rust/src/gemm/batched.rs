//! Weight-stationary batched GEMM engines — the multi-user decode path.
//!
//! The GEMV engines in [`crate::gemm`] are optimal when one request decodes
//! alone, but a serving worker with B in-flight requests would sweep every
//! packed weight column B times per scheduling round. These kernels walk
//! each weight column **once** and accumulate into all B output rows from B
//! per-row lookup tables (or B quantized activation rows), so decode
//! throughput scales with batch size instead of replaying weight reads.
//!
//! Layout contract: every kernel writes its accumulators `yt` in
//! **[n, b]** order — column j's B accumulators are contiguous at
//! `yt[j*b .. (j+1)*b]`. That keeps the per-column inner loop allocation-
//! free and lets the thread splitter cut on column boundaries
//! ([`par_chunks_mut_granular`] with `granule = b`). Callers scatter back
//! to row-major [b, n] during dequantization, which they must do anyway to
//! apply per-row scales.
//!
//! Dispatch: each public kernel resolves a backend once per call via
//! [`super::simd::active_backend`] (AVX2 / NEON / scalar; `PQUANT_SIMD`
//! and [`super::simd::set_simd_mode`] override) and runs its per-chunk
//! work through that backend. The `*_cols_scalar` functions below are the
//! original scalar loops, kept verbatim as the always-on bit-exactness
//! oracle — the SIMD paths must (and are property-tested to, in
//! `tests/simd_parity.rs`) reproduce them bit-for-bit. See
//! `docs/performance.md`.
//!
//! Bit-exactness: the integer kernels perform, per (row, column), exactly
//! the adds of the corresponding GEMV (reassociated only across i32
//! additions, which commute exactly), so results are bit-identical to the
//! per-row path (property-tested below and in
//! `tests/integration_batch.rs`). The f32 kernel preserves the GEMV's
//! k-major accumulation order, its skip-zero behavior, and one rounding
//! per multiply/add (no FMA), so it too is bit-identical in every mode.

use crate::quant::{PackedBits, PackedTernary};
use crate::util::threads::{num_threads, par_chunks_mut_granular};

use super::lut::Luts;
use super::simd::{self, Backend};
use super::TernaryLuts;

/// Floor on accumulator elements per thread before another scoped thread
/// is worth spawning (threads are spawned per call; tiny shapes should
/// stay single-threaded).
const MIN_ELEMS_PER_THREAD: usize = 1 << 12;

fn thread_count(total_elems: usize, cols: usize) -> usize {
    num_threads()
        .min(cols.max(1))
        .min(total_elems / MIN_ELEMS_PER_THREAD + 1)
}

/// Scalar oracle for [`lut_gemm_into`]'s per-chunk work: columns
/// `col0..col0 + chunk.len()/b` of the `[n, b]` accumulator, `b =
/// luts.len()`. Kept verbatim from the original kernel; every SIMD
/// backend must match it bit-for-bit.
pub fn lut_cols_scalar(luts: &[Luts], w: &PackedBits, col0: usize, chunk: &mut [i32]) {
    let b = luts.len();
    for (cj, accs) in chunk.chunks_exact_mut(b).enumerate() {
        let j = col0 + cj;
        let col = &w.bytes[j * w.bytes_per_col..(j + 1) * w.bytes_per_col];
        accs.fill(0);
        for (byte_idx, &byte) in col.iter().enumerate() {
            let g = byte_idx * 2;
            let lo = (byte & 0x0F) as usize;
            let hi = (byte >> 4) as usize;
            for (r, acc) in accs.iter_mut().enumerate() {
                let t = &luts[r].tables;
                *acc += unsafe {
                    // In bounds: g+1 < n_groups (callers assert) and
                    // lo/hi < 16 — same argument as lut_gemv_into.
                    *t.get_unchecked(g * 16 + lo) as i32
                        + *t.get_unchecked((g + 1) * 16 + hi) as i32
                };
            }
        }
    }
}

/// Batched LUT W1A8 GEMM: `yt[j*b + r] = Σ_groups luts[r][nibble(g, col j)]`
/// for `b = luts.len()` rows. Each packed column is read once for the whole
/// batch; with `b == 1` this degenerates to [`super::lut_gemv_into`] and is
/// bit-identical to it for every `b` and every dispatch backend.
pub fn lut_gemm_into(luts: &[Luts], w: &PackedBits, yt: &mut [i32]) {
    let b = luts.len();
    assert!(b > 0, "empty batch");
    assert_eq!(yt.len(), w.n * b);
    for l in luts {
        // Exactly the bound the unsafe indexing needs: the inner loop
        // reads nibble groups 0..2*bytes_per_col of each table.
        assert!(l.n_groups >= w.bytes_per_col * 2, "LUTs built for smaller k");
    }
    let threads = thread_count(yt.len(), w.n);
    let be = simd::active_backend();
    par_chunks_mut_granular(yt, threads, b, |_, start, chunk| {
        let col0 = start / b;
        match be {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => unsafe { simd::x86::lut_cols(luts, w, col0, chunk) },
            _ => lut_cols_scalar(luts, w, col0, chunk),
        }
    });
}

/// Scalar oracle for [`ternary_gemm_into`]'s per-chunk work (kept
/// verbatim; see [`lut_cols_scalar`]).
pub fn ternary_cols_scalar(luts: &[TernaryLuts], w: &PackedTernary, col0: usize, chunk: &mut [i32]) {
    let b = luts.len();
    for (cj, accs) in chunk.chunks_exact_mut(b).enumerate() {
        let j = col0 + cj;
        let col = &w.bytes[j * w.bytes_per_col..(j + 1) * w.bytes_per_col];
        accs.fill(0);
        for (g, &byte) in col.iter().enumerate() {
            for (r, acc) in accs.iter_mut().enumerate() {
                *acc += unsafe {
                    // in bounds: g < bytes_per_col <= n_groups, byte < 256
                    *luts[r].tables.get_unchecked(g * 256 + byte as usize) as i32
                };
            }
        }
    }
}

/// Batched packed-ternary GEMM over per-row byte-indexed tables; the
/// weight-stationary twin of [`super::ternary_gemv_into`].
pub fn ternary_gemm_into(luts: &[TernaryLuts], w: &PackedTernary, yt: &mut [i32]) {
    let b = luts.len();
    assert!(b > 0, "empty batch");
    assert_eq!(yt.len(), w.n * b);
    for l in luts {
        assert!(l.n_groups >= w.bytes_per_col, "LUTs built for smaller k");
    }
    let threads = thread_count(yt.len(), w.n);
    let be = simd::active_backend();
    par_chunks_mut_granular(yt, threads, b, |_, start, chunk| {
        let col0 = start / b;
        match be {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => unsafe { simd::x86::ternary_cols(luts, w, col0, chunk) },
            _ => ternary_cols_scalar(luts, w, col0, chunk),
        }
    });
}

/// Scalar oracle for [`i8_gemm_batch_into`]'s per-chunk work (kept
/// verbatim; see [`lut_cols_scalar`]).
pub fn i8_cols_scalar(
    xs: &[i8],
    w: &[i8],
    b: usize,
    k: usize,
    n: usize,
    col0: usize,
    chunk: &mut [i32],
) {
    let cols = chunk.len() / b;
    chunk.fill(0);
    for kk in 0..k {
        let wrow = &w[kk * n + col0..kk * n + col0 + cols];
        for r in 0..b {
            let xv = xs[r * k + kk] as i32;
            if xv == 0 {
                continue;
            }
            for (cj, &wv) in wrow.iter().enumerate() {
                chunk[cj * b + r] += xv * wv as i32;
            }
        }
    }
}

/// Batched INT8 GEMM with i32 accumulation: `xs` is [b, k] row-major
/// quantized activations, `w` is [k, n] row-major weights, `yt` is the
/// [n, b] accumulator. Walks `w` row-major once per batch step; exact
/// integer arithmetic, bit-identical to [`super::i8_gemv`] per row.
pub fn i8_gemm_batch_into(xs: &[i8], w: &[i8], b: usize, k: usize, n: usize, yt: &mut [i32]) {
    assert!(b > 0, "empty batch");
    assert_eq!(xs.len(), b * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(yt.len(), n * b);
    let threads = thread_count(yt.len(), n);
    let be = simd::active_backend();
    par_chunks_mut_granular(yt, threads, b, |_, start, chunk| {
        let col0 = start / b;
        match be {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => unsafe { simd::x86::i8_cols(xs, w, b, k, n, col0, chunk) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { simd::neon::i8_cols(xs, w, b, k, n, col0, chunk) },
            _ => i8_cols_scalar(xs, w, b, k, n, col0, chunk),
        }
    });
}

/// Scalar oracle for [`f32_gemm_batch_into`]'s per-chunk work (kept
/// verbatim; see [`lut_cols_scalar`]).
pub fn f32_cols_scalar(
    xs: &[f32],
    w: &[f32],
    b: usize,
    k: usize,
    n: usize,
    col0: usize,
    chunk: &mut [f32],
) {
    let cols = chunk.len() / b;
    chunk.fill(0.0);
    for kk in 0..k {
        let wrow = &w[kk * n + col0..kk * n + col0 + cols];
        for r in 0..b {
            let xv = xs[r * k + kk];
            if xv == 0.0 {
                continue;
            }
            for (cj, &wv) in wrow.iter().enumerate() {
                chunk[cj * b + r] += xv * wv;
            }
        }
    }
}

/// Batched f32 GEMM into a [n, b] accumulator, preserving
/// [`super::f32_gemv`]'s k-major accumulation order and skip-zero rows so
/// every output row is bit-identical to the GEMV path (the serving
/// lm_head and FP16-baseline batch engine). The SIMD paths vectorize
/// across output columns only — the per-element addition sequence is
/// untouched, so bit-exactness holds in every mode.
pub fn f32_gemm_batch_into(xs: &[f32], w: &[f32], b: usize, k: usize, n: usize, yt: &mut [f32]) {
    assert!(b > 0, "empty batch");
    assert_eq!(xs.len(), b * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(yt.len(), n * b);
    let threads = thread_count(yt.len(), n);
    let be = simd::active_backend();
    par_chunks_mut_granular(yt, threads, b, |_, start, chunk| {
        let col0 = start / b;
        match be {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => unsafe { simd::x86::f32_cols(xs, w, b, k, n, col0, chunk) },
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => unsafe { simd::neon::f32_cols(xs, w, b, k, n, col0, chunk) },
            _ => f32_cols_scalar(xs, w, b, k, n, col0, chunk),
        }
    });
}

#[cfg(test)]
mod tests {
    use super::super::{
        build_luts, build_ternary_luts, f32_gemv, i8_gemv, lut_gemv, ternary_gemv,
    };
    use super::*;
    use crate::quant::{pack_signs, pack_ternary};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_i8_rows(r: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (r.below(255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn lut_gemm_matches_per_row_gemv_bitexactly() {
        prop::check(71, 40, |r: &mut Rng| {
            let k = 1 + r.below(150);
            let n = 1 + r.below(20);
            let b = 1 + r.below(9);
            let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
            let xs = rand_i8_rows(r, b * k);
            (k, n, b, signs, xs)
        }, |(k, n, b, signs, xs)| {
            let w = pack_signs(signs, *k, *n);
            let luts: Vec<_> = (0..*b).map(|r| build_luts(&xs[r * k..(r + 1) * k], *k)).collect();
            let mut yt = vec![0i32; w.n * b];
            lut_gemm_into(&luts, &w, &mut yt);
            for r in 0..*b {
                let want = lut_gemv(&luts[r], &w);
                for j in 0..*n {
                    if yt[j * b + r] != want[j] {
                        return Err(format!("row {r} col {j}: {} vs {}", yt[j * b + r], want[j]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ternary_gemm_matches_per_row_gemv_bitexactly() {
        prop::check(72, 30, |r: &mut Rng| {
            let k = 1 + r.below(100);
            let n = 1 + r.below(16);
            let b = 1 + r.below(7);
            let vals: Vec<i8> = (0..k * n).map(|_| r.below(3) as i8 - 1).collect();
            let xs = rand_i8_rows(r, b * k);
            (k, n, b, vals, xs)
        }, |(k, n, b, vals, xs)| {
            let w = pack_ternary(vals, *k, *n);
            let luts: Vec<_> =
                (0..*b).map(|r| build_ternary_luts(&xs[r * k..(r + 1) * k], *k)).collect();
            let mut yt = vec![0i32; w.n * b];
            ternary_gemm_into(&luts, &w, &mut yt);
            for r in 0..*b {
                let want = ternary_gemv(&xs[r * k..(r + 1) * k], &w);
                for j in 0..*n {
                    if yt[j * b + r] != want[j] {
                        return Err(format!("row {r} col {j}: {} vs {}", yt[j * b + r], want[j]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn i8_gemm_batch_matches_per_row_gemv_bitexactly() {
        prop::check(73, 30, |r: &mut Rng| {
            let k = 1 + r.below(80);
            let n = 1 + r.below(20);
            let b = 1 + r.below(9);
            let w = rand_i8_rows(r, k * n);
            let xs = rand_i8_rows(r, b * k);
            (k, n, b, w, xs)
        }, |(k, n, b, w, xs)| {
            let mut yt = vec![0i32; n * b];
            i8_gemm_batch_into(xs, w, *b, *k, *n, &mut yt);
            for r in 0..*b {
                let want = i8_gemv(&xs[r * k..(r + 1) * k], w, *k, *n);
                for j in 0..*n {
                    if yt[j * b + r] != want[j] {
                        return Err(format!("row {r} col {j}: {} vs {}", yt[j * b + r], want[j]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn f32_gemm_batch_matches_per_row_gemv_bitexactly() {
        prop::check(74, 30, |r: &mut Rng| {
            let k = 1 + r.below(60);
            let n = 1 + r.below(20);
            let b = 1 + r.below(9);
            let mut w = r.normal_vec(k * n);
            let mut xs = r.normal_vec(b * k);
            // sprinkle exact zeros so the skip-zero path is exercised
            for i in (0..w.len()).step_by(7) {
                w[i] = 0.0;
            }
            for i in (0..xs.len()).step_by(5) {
                xs[i] = 0.0;
            }
            (k, n, b, w, xs)
        }, |(k, n, b, w, xs)| {
            let mut yt = vec![0f32; n * b];
            f32_gemm_batch_into(xs, w, *b, *k, *n, &mut yt);
            for r in 0..*b {
                let want = f32_gemv(&xs[r * k..(r + 1) * k], w, *k, *n);
                for j in 0..*n {
                    // bit-exact, not approximate: same adds in same order
                    if yt[j * b + r].to_bits() != want[j].to_bits() {
                        return Err(format!("row {r} col {j}: {} vs {}", yt[j * b + r], want[j]));
                    }
                }
            }
            Ok(())
        });
    }
}
