//! Quantized + baseline matmul engines — the compute substrate of the
//! inference path and the workloads behind the paper's Figure 8
//! ("computation time across components").
//!
//! * [`lut`] — T-MAC-style table-lookup W1A8 GEMV (Appendix A): groups of
//!   4 packed sign bits index a 16-entry table of precomputed partial sums;
//!   the matmul becomes lookups + adds, no multiplies.
//! * [`f32_gemm`]/[`f32_gemv`] — the FP16-baseline engine.
//! * [`i8_gemm`]/[`i8_gemv`] — INT8 engine for the high-precision branch.
//! * [`ternary_gemv`] — packed 2-bit BitNet1.58 engine.
//! * [`batched`] — weight-stationary batched twins of every engine: each
//!   packed weight column is read **once** per batch step and accumulated
//!   into B output rows (the multi-user decode path; integer accumulation
//!   keeps every row bit-identical to the GEMV engines).
//! * [`simd`] — runtime CPU-feature dispatch (AVX2 / NEON / scalar) for
//!   the batched engines and the LUT-family GEMV walks. Scalar loops stay
//!   as the bit-exactness oracle; `PQUANT_SIMD=off` or
//!   [`set_simd_mode`] force it. Design + measured ratios:
//!   `docs/performance.md`.

pub mod batched;
pub mod lut;
pub mod simd;

pub use batched::{f32_gemm_batch_into, i8_gemm_batch_into, lut_gemm_into, ternary_gemm_into};
pub use lut::{build_luts, build_luts_into, lut_gemv, lut_gemv_into};
pub use simd::{active_backend, available_modes, set_simd_mode, simd_mode, Backend, SimdMode};

use crate::quant::PackedTernary;
use crate::util::threads::{par_chunks_mut, par_chunks_mut_granular};

/// Per-chunk row loop of [`f32_gemm`]: computes output rows starting at
/// `row0` into a pre-zeroed `chunk` (whole rows, `chunk.len() % n == 0`).
/// Factored out so the straddle regression test below can drive it under
/// both the granular and the (buggy) non-granular splitter.
fn f32_gemm_rows(a: &[f32], b: &[f32], k: usize, n: usize, row0: usize, chunk: &mut [f32]) {
    let rows = chunk.len() / n;
    for r in 0..rows {
        let i = row0 + r;
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut chunk[r * n..(r + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Row-major f32 GEMM: c[m,n] = a[m,k] · b[k,n], blocked over k and
/// threaded over rows of the output. Uses the granular splitter with
/// `granule = n` so chunk boundaries always land on row boundaries —
/// the plain splitter could hand a thread a chunk straddling two rows
/// (whenever `num_threads() < m` doesn't divide m), which silently
/// dropped and misattributed partial rows.
pub fn f32_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    let threads = crate::util::threads::num_threads().min(m.max(1));
    par_chunks_mut_granular(&mut c, threads, n, |_, start, chunk| {
        f32_gemm_rows(a, b, k, n, start / n, chunk);
    });
    c
}

/// f32 GEMV: y[n] = x[k] · b[k,n] (b row-major). The batch=1 decode path
/// of the FP16 baseline.
pub fn f32_gemv(x: &[f32], b: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), k);
    assert_eq!(b.len(), k * n);
    let mut y = vec![0.0f32; n];
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let brow = &b[kk * n..(kk + 1) * n];
        for (yv, &bv) in y.iter_mut().zip(brow) {
            *yv += xv * bv;
        }
    }
    y
}

/// Per-chunk row loop of [`i8_gemm`] (see [`f32_gemm_rows`]).
fn i8_gemm_rows(a: &[i8], b: &[i8], k: usize, n: usize, row0: usize, chunk: &mut [i32]) {
    let rows = chunk.len() / n;
    for r in 0..rows {
        let i = row0 + r;
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut chunk[r * n..(r + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
}

/// INT8 GEMM with i32 accumulation: c[m,n] = a_q[m,k] · b_q[k,n].
/// Exact integer arithmetic (|k|·127² < 2³¹ for every config here).
/// Granular row splitting for the same reason as [`f32_gemm`].
pub fn i8_gemm(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0i32; m * n];
    let threads = crate::util::threads::num_threads().min(m.max(1));
    par_chunks_mut_granular(&mut c, threads, n, |_, start, chunk| {
        i8_gemm_rows(a, b, k, n, start / n, chunk);
    });
    c
}

/// INT8 GEMV: y[n] = x_q[k] · b_q[k,n], i32 accumulation.
pub fn i8_gemv(x: &[i8], b: &[i8], k: usize, n: usize) -> Vec<i32> {
    assert_eq!(x.len(), k);
    assert_eq!(b.len(), k * n);
    let mut y = vec![0i32; n];
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let xv = xv as i32;
        let brow = &b[kk * n..(kk + 1) * n];
        for (yv, &bv) in y.iter_mut().zip(brow) {
            *yv += xv * bv as i32;
        }
    }
    y
}

/// Packed-ternary GEMV (BitNet1.58 engine): y[n] = x_q[k] · T[k,n] with
/// T ∈ {-1,0,+1} stored 2 bits/weight column-major. i32 accumulation;
/// multiply-free.
///
/// Perf note (EXPERIMENTS.md §Perf): the first implementation decoded the
/// 2-bit codes with a branchy inner loop and ran ~50× slower than the
/// 1-bit LUT path (1046 ms vs 25 ms per 7B-block decode). This version
/// applies the same T-MAC treatment as [`lut::lut_gemv`]: one 256-entry
/// table per group of 4 rows, indexed directly by the packed byte —
/// lookups + adds only.
pub fn ternary_gemv(x: &[i8], w: &PackedTernary) -> Vec<i32> {
    let luts = build_ternary_luts(x, w.k);
    let mut y = vec![0i32; w.n];
    ternary_gemv_into(&luts, w, &mut y);
    y
}

/// Per-group byte-indexed tables for the ternary path. i16 is safe:
/// |4·127| = 508.
pub struct TernaryLuts {
    pub tables: Vec<i16>, // n_groups × 256
    pub n_groups: usize,
}

/// Build ternary tables: table[g][byte] = Σ_l code(byte, l)·x[4g+l],
/// code ∈ {00→0, 01→+1, 10→−1} (11 never occurs in packed data).
/// Built incrementally: clear the lowest set 2-bit field and add its
/// contribution — 256 adds per group.
pub fn build_ternary_luts(x: &[i8], k: usize) -> TernaryLuts {
    let mut out = TernaryLuts { tables: Vec::new(), n_groups: 0 };
    build_ternary_luts_into(x, k, &mut out);
    out
}

/// [`build_ternary_luts`] into caller-owned storage (batched decode
/// rebuilds per-row tables every token without allocating).
pub fn build_ternary_luts_into(x: &[i8], k: usize, out: &mut TernaryLuts) {
    let n_groups = k.div_ceil(4);
    out.n_groups = n_groups;
    let tables = &mut out.tables;
    tables.clear();
    tables.resize(n_groups * 256, 0);
    for g in 0..n_groups {
        let base = g * 4;
        let mut xs = [0i16; 4];
        for l in 0..4 {
            if base + l < k {
                xs[l] = x[base + l] as i16;
            }
        }
        let t = &mut tables[g * 256..(g + 1) * 256];
        // t[0] = 0 already; fill the rest from the cleared-field prefix
        for b in 1usize..256 {
            let field = b.trailing_zeros() as usize / 2; // lowest non-zero lane
            let code = (b >> (field * 2)) & 0b11;
            let prev = b & !(0b11 << (field * 2));
            let contrib = match code {
                0b01 => xs[field],
                0b10 => -xs[field],
                _ => 0, // 0b11 unreachable in real data
            };
            t[b] = t[prev] + contrib;
        }
    }
}

/// Allocation-free ternary GEMV over prebuilt tables. Dispatches to the
/// AVX2 table walk when available (a GEMV is the `b = 1` case of the
/// batched kernel, whose `[n, 1]` accumulator layout *is* `y`); integer
/// adds commute, so every backend is bit-identical to the scalar walk.
pub fn ternary_gemv_into(luts: &TernaryLuts, w: &PackedTernary, y: &mut [i32]) {
    assert_eq!(y.len(), w.n);
    assert!(luts.n_groups >= w.bytes_per_col, "LUTs built for smaller k");
    let threads = crate::util::threads::num_threads().min(w.n.max(1));
    let be = simd::active_backend();
    par_chunks_mut(y, threads, |_, start, chunk| {
        match be {
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => unsafe {
                simd::x86::ternary_cols(std::slice::from_ref(luts), w, start, chunk)
            },
            _ => {
                for (jj, acc) in chunk.iter_mut().enumerate() {
                    let j = start + jj;
                    let col = &w.bytes[j * w.bytes_per_col..(j + 1) * w.bytes_per_col];
                    let mut sum = 0i32;
                    for (g, &byte) in col.iter().enumerate() {
                        sum += unsafe {
                            // in bounds: g < bytes_per_col <= n_groups, byte < 256
                            *luts.tables.get_unchecked(g * 256 + byte as usize) as i32
                        };
                    }
                    *acc = sum;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack_ternary;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn f32_gemm_matches_naive() {
        prop::check(21, 20, |r: &mut Rng| {
            let m = 1 + r.below(17);
            let k = 1 + r.below(33);
            let n = 1 + r.below(17);
            let a = r.normal_vec(m * k);
            let b = r.normal_vec(k * n);
            (m, k, n, a, b)
        }, |(m, k, n, a, b)| {
            let got = f32_gemm(a, b, *m, *k, *n);
            let want = naive_f32(a, b, *m, *k, *n);
            for (g, w) in got.iter().zip(&want) {
                if (g - w).abs() > 1e-3 {
                    return Err(format!("{g} vs {w}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemv_matches_gemm_row() {
        let mut r = Rng::new(5);
        let (k, n) = (37, 19);
        let x = r.normal_vec(k);
        let b = r.normal_vec(k * n);
        let y = f32_gemv(&x, &b, k, n);
        let c = f32_gemm(&x, &b, 1, k, n);
        assert_eq!(y, c);
    }

    #[test]
    fn i8_gemm_exact() {
        prop::check(22, 20, |r: &mut Rng| {
            let m = 1 + r.below(9);
            let k = 1 + r.below(65);
            let n = 1 + r.below(17);
            let a: Vec<i8> = (0..m * k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..k * n).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            (m, k, n, a, b)
        }, |(m, k, n, a, b)| {
            let got = i8_gemm(a, b, *m, *k, *n);
            for i in 0..*m {
                for j in 0..*n {
                    let want: i32 = (0..*k)
                        .map(|kk| a[i * k + kk] as i32 * b[kk * n + j] as i32)
                        .sum();
                    if got[i * n + j] != want {
                        return Err(format!("({i},{j}): {} vs {want}", got[i * n + j]));
                    }
                }
            }
            Ok(())
        });
    }

    /// Regression for the row-straddling parallel split. The old
    /// `f32_gemm`/`i8_gemm` used the plain splitter, whose chunk
    /// boundaries only land on row boundaries when the chunk size happens
    /// to be a multiple of `n`; with m=3, n=10 and 2 chunks, the 30-elem
    /// output splits 15+15 — chunk 1 starts mid-row, `start / n`
    /// misattributes the activation row, and `chunk.len() / n` drops the
    /// trailing half-row entirely. Driven through the factored row loops
    /// so the bad splitting is forced deterministically on any core count
    /// (the thread-cap version lives in `tests/gemm_straddle.rs`).
    #[test]
    fn granular_split_fixes_row_straddling_chunks() {
        let mut r = Rng::new(77);
        let (m, k, n) = (3usize, 8usize, 10usize);
        let a = r.normal_vec(m * k);
        let b = r.normal_vec(k * n);
        let want = naive_f32(&a, &b, m, k, n);

        // Reproduce the old bug: a non-granular 2-way split straddles.
        let mut c_old = vec![0.0f32; m * n];
        crate::util::threads::par_chunks_mut(&mut c_old, 2, |_, start, chunk| {
            f32_gemm_rows(&a, &b, k, n, start / n, chunk);
        });
        let old_matches = c_old.iter().zip(&want).all(|(g, w)| (g - w).abs() < 1e-3);
        assert!(!old_matches, "straddling split should reproduce the old bug");

        // The granular splitter is correct for every chunk count.
        for chunks in 1..=6 {
            let mut c = vec![0.0f32; m * n];
            par_chunks_mut_granular(&mut c, chunks, n, |_, start, chunk| {
                f32_gemm_rows(&a, &b, k, n, start / n, chunk);
            });
            for (g, w) in c.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "chunks={chunks}: {g} vs {w}");
            }
        }

        // Same shape through the integer engine, exactly.
        let ai: Vec<i8> = (0..m * k).map(|i| (i as i32 % 255 - 127) as i8).collect();
        let bi: Vec<i8> = (0..k * n).map(|i| (i as i32 * 7 % 255 - 127) as i8).collect();
        let mut want_i = vec![0i32; m * n];
        for i in 0..m {
            for j in 0..n {
                want_i[i * n + j] =
                    (0..k).map(|kk| ai[i * k + kk] as i32 * bi[kk * n + j] as i32).sum();
            }
        }
        let mut ci_old = vec![0i32; m * n];
        crate::util::threads::par_chunks_mut(&mut ci_old, 2, |_, start, chunk| {
            i8_gemm_rows(&ai, &bi, k, n, start / n, chunk);
        });
        assert_ne!(ci_old, want_i, "straddling split should reproduce the old bug");
        for chunks in 1..=6 {
            let mut ci = vec![0i32; m * n];
            par_chunks_mut_granular(&mut ci, chunks, n, |_, start, chunk| {
                i8_gemm_rows(&ai, &bi, k, n, start / n, chunk);
            });
            assert_eq!(ci, want_i, "chunks={chunks}");
        }
    }

    #[test]
    fn ternary_gemv_exact() {
        prop::check(23, 30, |r: &mut Rng| {
            let k = 1 + r.below(100);
            let n = 1 + r.below(20);
            let vals: Vec<i8> = (0..k * n).map(|_| r.below(3) as i8 - 1).collect();
            let x: Vec<i8> = (0..k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            (k, n, vals, x)
        }, |(k, n, vals, x)| {
            let p = pack_ternary(vals, *k, *n);
            let got = ternary_gemv(x, &p);
            for j in 0..*n {
                let want: i32 = (0..*k)
                    .map(|i| vals[i * n + j] as i32 * x[i] as i32)
                    .sum();
                if got[j] != want {
                    return Err(format!("col {j}: {} vs {want}", got[j]));
                }
            }
            Ok(())
        });
    }
}
