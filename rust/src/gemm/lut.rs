//! T-MAC-style LUT W1A8 GEMV (paper Appendix A).
//!
//! "If a 1-bit matrix is partitioned into groups of four elements, there
//!  are only 2⁴ possible combinations per group … the results of its
//!  multiplication with all possible bit patterns can be precomputed and
//!  stored in a lookup table."
//!
//! Given the INT8 activation vector x[k] (zero-padded to the packed byte
//! boundary), we build one 16-entry table per group of 4 rows:
//!
//! ```text
//! table[g][p] = Σ_{i<4} (p_i ? +x[4g+i] : −x[4g+i])    (i16 fits: 4·127 = 508)
//! ```
//!
//! built incrementally in 16 adds per group via the subset trick
//! (flip one bit = add 2·x_i).  The GEMV then walks each packed weight
//! column nibble-by-nibble accumulating table hits in i32 — no multiplies
//! anywhere in the inner loop.
//!
//! Table-build cost is O(4·k) per *token* and is amortized over all n
//! output columns, exactly the T-MAC trade.

use crate::quant::PackedBits;
use crate::util::threads::{num_threads, par_chunks_mut};

/// Per-group lookup tables for one activation vector.
#[derive(Debug, Clone)]
pub struct Luts {
    /// n_groups × 16, flattened. i16: |4·127| = 508 < i16::MAX.
    pub tables: Vec<i16>,
    pub n_groups: usize,
}

/// Build the group-of-4 tables for activations `x` (length ≥ k; entries
/// past k must be zero — `lut_gemv` pads internally).
pub fn build_luts(x: &[i8], k: usize) -> Luts {
    let mut out = Luts { tables: Vec::new(), n_groups: 0 };
    build_luts_into(x, k, &mut out);
    out
}

/// [`build_luts`] into caller-owned storage — the batched decode path
/// rebuilds per-row tables every token, so the `Vec` must be reusable
/// (steady state performs no allocation once capacity is warm).
pub fn build_luts_into(x: &[i8], k: usize, out: &mut Luts) {
    let n_groups = k.div_ceil(8) * 2; // nibbles per packed byte column
    out.n_groups = n_groups;
    let tables = &mut out.tables;
    tables.clear();
    tables.resize(n_groups * 16, 0);
    for g in 0..n_groups {
        let base = g * 4;
        let mut xs = [0i16; 4];
        for i in 0..4 {
            if base + i < k {
                xs[i] = x[base + i] as i16;
            }
        }
        let t = &mut tables[g * 16..(g + 1) * 16];
        // p = 0: all bits clear = all −x
        t[0] = -(xs[0] + xs[1] + xs[2] + xs[3]);
        for p in 1usize..16 {
            let low = p.trailing_zeros() as usize;
            t[p] = t[p & (p - 1)] + 2 * xs[low];
        }
    }
}

/// LUT GEMV: y[n] = Σ_groups table[g][nibble(g, col)], i32 accumulation.
/// `w` is the packed ±1 weight matrix; `luts` from [`build_luts`] over the
/// same k.
pub fn lut_gemv(luts: &Luts, w: &PackedBits) -> Vec<i32> {
    let mut y = vec![0i32; w.n];
    lut_gemv_into(luts, w, &mut y);
    y
}

/// Allocation-free variant for the serving hot loop. Dispatches to the
/// AVX2 gather-based table walk when available (the GEMV is the `b = 1`
/// case of the batched kernel: its `[n, 1]` accumulator layout is exactly
/// `y`); i32 adds commute, so every backend is bit-identical.
pub fn lut_gemv_into(luts: &Luts, w: &PackedBits, y: &mut [i32]) {
    assert_eq!(y.len(), w.n);
    // The unsafe nibble walk reads groups 0..2*bytes_per_col, so that —
    // not ceil-divided k — is the bound that keeps it in range for
    // hand-built Luts.
    assert!(luts.n_groups >= w.bytes_per_col * 2, "LUTs built for smaller k");
    let threads = num_threads().min(w.n.max(1));
    let be = super::simd::active_backend();
    par_chunks_mut(y, threads, |_, start, chunk| {
        #[cfg(target_arch = "x86_64")]
        if be == super::simd::Backend::Avx2 {
            unsafe {
                super::simd::x86::lut_cols(std::slice::from_ref(luts), w, start, chunk);
            }
            return;
        }
        let _ = be;
        for (jj, acc) in chunk.iter_mut().enumerate() {
            let j = start + jj;
            let col = &w.bytes[j * w.bytes_per_col..(j + 1) * w.bytes_per_col];
            let mut sum = 0i32;
            for (byte_idx, &byte) in col.iter().enumerate() {
                let g = byte_idx * 2;
                let lo = (byte & 0x0F) as usize;
                let hi = (byte >> 4) as usize;
                sum += unsafe {
                    // In-bounds by construction: g+1 < n_groups because
                    // bytes_per_col*2 == n_groups (assert above), and
                    // lo/hi < 16.
                    *luts.tables.get_unchecked(g * 16 + lo) as i32
                        + *luts.tables.get_unchecked((g + 1) * 16 + hi) as i32
                };
            }
            *acc = sum;
        }
    });
}

/// End-to-end W1A8 linear on the LUT path: quantize x per-token, build
/// tables, GEMV, dequantize with λ/γ. Returns f32 outputs.
pub fn w1a8_linear(x: &[f32], w: &PackedBits, lambda: f32) -> Vec<f32> {
    let (x_q, gammas) = crate::quant::quantize_i8_rows(x, 1, x.len());
    let luts = build_luts(&x_q, w.k);
    let y = lut_gemv(&luts, w);
    let scale = lambda / gammas[0];
    y.into_iter().map(|v| v as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack_signs;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Naive ±1 GEMV ground truth.
    fn naive(x: &[i8], signs: &[bool], k: usize, n: usize) -> Vec<i32> {
        (0..n)
            .map(|j| {
                (0..k)
                    .map(|i| {
                        let s = if signs[i * n + j] { 1 } else { -1 };
                        s * x[i] as i32
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn lut_gemv_exactly_matches_naive() {
        prop::check(31, 60, |r: &mut Rng| {
            let k = 1 + r.below(200);
            let n = 1 + r.below(24);
            let signs: Vec<bool> = (0..k * n).map(|_| r.below(2) == 1).collect();
            let x: Vec<i8> = (0..k).map(|_| (r.below(255) as i32 - 127) as i8).collect();
            (k, n, signs, x)
        }, |(k, n, signs, x)| {
            let w = pack_signs(signs, *k, *n);
            let luts = build_luts(x, *k);
            let got = lut_gemv(&luts, &w);
            let want = naive(x, signs, *k, *n);
            if got == want { Ok(()) } else { Err(format!("{got:?} vs {want:?}")) }
        });
    }

    #[test]
    fn table_subset_trick_correct() {
        let x: Vec<i8> = vec![3, -5, 7, 11];
        let luts = build_luts(&x, 4);
        for p in 0..16usize {
            let want: i16 = (0..4)
                .map(|i| if p >> i & 1 == 1 { x[i] as i16 } else { -(x[i] as i16) })
                .sum();
            assert_eq!(luts.tables[p], want, "pattern {p:#06b}");
        }
    }

    #[test]
    fn padding_rows_contribute_zero() {
        // k = 5 (3 pad bits in the first byte's high nibble + more)
        let k = 5;
        let n = 2;
        let signs = vec![true; k * n];
        let x: Vec<i8> = vec![1, 2, 3, 4, 5];
        let w = pack_signs(&signs, k, n);
        let luts = build_luts(&x, k);
        let y = lut_gemv(&luts, &w);
        assert_eq!(y, vec![15, 15]);
    }

    #[test]
    fn w1a8_linear_close_to_float() {
        let mut r = Rng::new(9);
        let k = 256;
        let n = 16;
        let wf = r.normal_vec(k * n);
        let b = crate::quant::binarize(&wf);
        let packed = pack_signs(&b.signs, k, n);
        let x = r.normal_vec(k);
        let got = w1a8_linear(&x, &packed, b.lambda);
        // ground truth: x · dequant(w)
        let deq = crate::quant::dequant_binary(&b);
        let want = crate::gemm::f32_gemv(&x, &deq, k, n);
        for (g, w) in got.iter().zip(&want) {
            // INT8 activation quantization error only
            assert!((g - w).abs() < 0.05 * (want.iter().map(|v| v.abs()).fold(0.0f32, f32::max) + 1.0),
                "{g} vs {w}");
        }
    }
}
