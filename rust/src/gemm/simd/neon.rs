//! NEON inner loops for the dense batched GEMM engines (aarch64).
//!
//! Mirrors the AVX2 register-blocking design at 128-bit width: per batch
//! row, an 8-column accumulator micro-tile lives in two q registers
//! across the whole k sweep, weight rows stream 8 columns at a time, and
//! column tiles are sized to keep a tile's weight slab L2-resident across
//! the `b` row sweeps. Integer accumulation uses `vmlaq_s32` (exact); the
//! f32 kernel uses separate `vmulq`/`vaddq` — never `vfmaq`, whose fused
//! rounding would break bit-exactness with the scalar oracle — with the
//! oracle's ascending-k order and skip-zero predicate intact.
//!
//! The LUT/ternary table walks stay on the scalar oracle on aarch64: a
//! `vqtbl`-based 16-lane walk needs a column-interleaved byte layout to
//! beat scalar and is tracked as a follow-on in `docs/performance.md`.
//! NEON is baseline on aarch64, so no runtime detection is needed and
//! these are plain `unsafe fn`s (the dispatcher still honors
//! `PQUANT_SIMD=off`).

use core::arch::aarch64::*;

use super::col_tile;

/// NEON path for [`crate::gemm::batched::i8_gemm_batch_into`]'s per-chunk
/// work.
///
/// # Safety
///
/// Caller must guarantee `xs.len() >= b*k`, `w.len() == k*n`,
/// `chunk.len()` a multiple of `b`, and the chunk's column range
/// `col0..col0 + chunk.len()/b` within `n`.
pub unsafe fn i8_cols(
    xs: &[i8],
    w: &[i8],
    b: usize,
    k: usize,
    n: usize,
    col0: usize,
    chunk: &mut [i32],
) {
    let cols = chunk.len() / b;
    chunk.fill(0);
    let cols8 = cols & !7;
    let tile = (col_tile(k, 1) / 2).max(8) & !7;
    let mut j0 = 0usize;
    while j0 < cols8 {
        let j1 = (j0 + tile).min(cols8);
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut jm = j0;
            while jm < j1 {
                let mut acc0 = vdupq_n_s32(0);
                let mut acc1 = vdupq_n_s32(0);
                for kk in 0..k {
                    let xv = *xrow.add(kk);
                    if xv == 0 {
                        // Exact for integers; matches the oracle's
                        // skip-zero predicate.
                        continue;
                    }
                    let wp = w.as_ptr().add(kk * n + col0 + jm);
                    let xb = vdupq_n_s32(xv as i32);
                    let w16 = vmovl_s8(vld1_s8(wp));
                    acc0 = vmlaq_s32(acc0, xb, vmovl_s16(vget_low_s16(w16)));
                    acc1 = vmlaq_s32(acc1, xb, vmovl_s16(vget_high_s16(w16)));
                }
                let mut buf = [0i32; 8];
                vst1q_s32(buf.as_mut_ptr(), acc0);
                vst1q_s32(buf.as_mut_ptr().add(4), acc1);
                for (l, &v) in buf.iter().enumerate() {
                    *chunk.get_unchecked_mut((jm + l) * b + r) = v;
                }
                jm += 8;
            }
        }
        j0 = j1;
    }
    // Remainder columns (< 8): scalar, same ascending-k order.
    for cj in cols8..cols {
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut sum = 0i32;
            for kk in 0..k {
                let xv = *xrow.add(kk);
                if xv == 0 {
                    continue;
                }
                sum += xv as i32 * *w.get_unchecked(kk * n + col0 + cj) as i32;
            }
            *chunk.get_unchecked_mut(cj * b + r) = sum;
        }
    }
}

/// NEON path for [`crate::gemm::batched::f32_gemm_batch_into`]'s
/// per-chunk work; bit-identical to the scalar oracle (see module docs).
///
/// # Safety
///
/// Caller must guarantee `xs.len() >= b*k`, `w.len() == k*n`,
/// `chunk.len()` a multiple of `b`, and the chunk's column range within
/// `n`.
pub unsafe fn f32_cols(
    xs: &[f32],
    w: &[f32],
    b: usize,
    k: usize,
    n: usize,
    col0: usize,
    chunk: &mut [f32],
) {
    let cols = chunk.len() / b;
    chunk.fill(0.0);
    let cols8 = cols & !7;
    let tile = (col_tile(k, 4) / 2).max(8) & !7;
    let mut j0 = 0usize;
    while j0 < cols8 {
        let j1 = (j0 + tile).min(cols8);
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut jm = j0;
            while jm < j1 {
                let mut acc0 = vdupq_n_f32(0.0);
                let mut acc1 = vdupq_n_f32(0.0);
                for kk in 0..k {
                    let xv = *xrow.add(kk);
                    if xv == 0.0 {
                        // The oracle's exact predicate (also skips -0.0).
                        continue;
                    }
                    let wp = w.as_ptr().add(kk * n + col0 + jm);
                    let xb = vdupq_n_f32(xv);
                    // mul then add, never vfmaq: one rounding per op,
                    // exactly like the scalar `*cv += av * bv`.
                    acc0 = vaddq_f32(acc0, vmulq_f32(xb, vld1q_f32(wp)));
                    acc1 = vaddq_f32(acc1, vmulq_f32(xb, vld1q_f32(wp.add(4))));
                }
                let mut buf = [0f32; 8];
                vst1q_f32(buf.as_mut_ptr(), acc0);
                vst1q_f32(buf.as_mut_ptr().add(4), acc1);
                for (l, &v) in buf.iter().enumerate() {
                    *chunk.get_unchecked_mut((jm + l) * b + r) = v;
                }
                jm += 8;
            }
        }
        j0 = j1;
    }
    for cj in cols8..cols {
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut sum = 0f32;
            for kk in 0..k {
                let xv = *xrow.add(kk);
                if xv == 0.0 {
                    continue;
                }
                sum += xv * *w.get_unchecked(kk * n + col0 + cj);
            }
            *chunk.get_unchecked_mut(cj * b + r) = sum;
        }
    }
}
