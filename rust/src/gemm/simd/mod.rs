//! Runtime CPU-feature dispatch for the GEMM inner loops.
//!
//! The batched engines in [`crate::gemm::batched`] and the GEMV LUT walks
//! ship two implementations per kernel: the original scalar loop (kept
//! verbatim as the always-on bit-exactness oracle) and an explicit-SIMD
//! path — stable `std::arch` AVX2 on x86_64 ([`x86`]), NEON on aarch64
//! ([`neon`]). Selection happens once per kernel call from three inputs,
//! in priority order:
//!
//! 1. [`set_simd_mode`] — a process-global programmatic override for tests
//!    and A/B benching.
//! 2. The `PQUANT_SIMD` environment variable, read once on first use:
//!    `off`/`0`/`scalar` force the oracle, `avx2`/`neon` force a backend
//!    (falling back to scalar if the CPU lacks it), anything else (or
//!    unset) means auto-detect.
//! 3. Auto-detection: `is_x86_feature_detected!("avx2")` on x86_64 (NEON
//!    is baseline on aarch64, no detection needed).
//!
//! Bit-exactness contract: the integer SIMD kernels perform exactly the
//! adds of the scalar oracle, reassociated only across i32 additions —
//! which commute exactly — so outputs are bit-identical in every mode
//! (property-tested in `tests/simd_parity.rs`). The f32 kernel is
//! vectorized across output *columns* with the reduction dimension kept
//! k-major and scalar-broadcast, no FMA contraction and no reassociation,
//! so it too is bit-identical to the oracle.
//!
//! See `docs/performance.md` for the tiling/prefetch design and measured
//! scalar-vs-SIMD ratios.

use std::sync::atomic::{AtomicU8, Ordering};

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

/// Requested dispatch policy (what [`set_simd_mode`] and `PQUANT_SIMD`
/// express). `Auto` resolves against the running CPU; forcing a backend
/// the CPU lacks degrades to `Scalar` rather than faulting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdMode {
    Auto,
    Scalar,
    Avx2,
    Neon,
}

/// Resolved per-call backend the kernels actually branch on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

const MODE_UNSET: u8 = 0;
const MODE_AUTO: u8 = 1;
const MODE_SCALAR: u8 = 2;
const MODE_AVX2: u8 = 3;
const MODE_NEON: u8 = 4;

/// Process-global mode. `MODE_UNSET` means "consult `PQUANT_SIMD` on first
/// use"; [`set_simd_mode`] writes a resolved value and wins thereafter.
static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

fn encode(m: SimdMode) -> u8 {
    match m {
        SimdMode::Auto => MODE_AUTO,
        SimdMode::Scalar => MODE_SCALAR,
        SimdMode::Avx2 => MODE_AVX2,
        SimdMode::Neon => MODE_NEON,
    }
}

/// Override the dispatch mode for this process (tests, benches, embedders).
/// `SimdMode::Auto` restores hardware auto-detection; note it does *not*
/// re-read `PQUANT_SIMD`.
pub fn set_simd_mode(mode: SimdMode) {
    MODE.store(encode(mode), Ordering::Relaxed);
}

fn mode_from_env() -> u8 {
    match std::env::var("PQUANT_SIMD") {
        Ok(v) => match v.to_ascii_lowercase().as_str() {
            "off" | "0" | "scalar" => MODE_SCALAR,
            "avx2" => MODE_AVX2,
            "neon" => MODE_NEON,
            _ => MODE_AUTO,
        },
        Err(_) => MODE_AUTO,
    }
}

fn mode_bits() -> u8 {
    match MODE.load(Ordering::Relaxed) {
        MODE_UNSET => {
            let m = mode_from_env();
            // First resolver wins; a concurrent set_simd_mode overrides
            // whatever lands here on its next store anyway.
            let _ = MODE.compare_exchange(MODE_UNSET, m, Ordering::Relaxed, Ordering::Relaxed);
            MODE.load(Ordering::Relaxed)
        }
        m => m,
    }
}

/// The currently requested mode, with the environment already applied.
pub fn simd_mode() -> SimdMode {
    match mode_bits() {
        MODE_SCALAR => SimdMode::Scalar,
        MODE_AVX2 => SimdMode::Avx2,
        MODE_NEON => SimdMode::Neon,
        _ => SimdMode::Auto,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Backend {
    if is_x86_feature_detected!("avx2") {
        Backend::Avx2
    } else {
        Backend::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Backend {
    Backend::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Backend {
    Backend::Scalar
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// Resolve the backend the kernels should branch on for this call. Cheap:
/// one relaxed atomic load after first use.
pub fn active_backend() -> Backend {
    match mode_bits() {
        MODE_SCALAR => Backend::Scalar,
        MODE_AVX2 => {
            if avx2_available() {
                Backend::Avx2
            } else {
                Backend::Scalar
            }
        }
        MODE_NEON => {
            if cfg!(target_arch = "aarch64") {
                Backend::Neon
            } else {
                Backend::Scalar
            }
        }
        _ => detect(),
    }
}

/// Every mode this CPU can actually honor (always includes `Scalar`);
/// the dispatch parity test iterates this.
pub fn available_modes() -> Vec<SimdMode> {
    let mut v = vec![SimdMode::Scalar];
    if avx2_available() {
        v.push(SimdMode::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    v.push(SimdMode::Neon);
    v
}

/// Column-byte block length for the LUT-family kernels: sized so the table
/// slab one block touches (`per_byte_bytes` across all batch rows) stays
/// within half a typical 512 KiB L2 while the block's weight bytes stream
/// through — the cache-blocked tiling of the packed weight planes.
#[allow(dead_code)] // referenced only by the arch-gated SIMD backends
pub(crate) fn byte_block(bytes_per_col: usize, per_byte_bytes: usize) -> usize {
    const L2_BUDGET: usize = 256 * 1024;
    (L2_BUDGET / per_byte_bytes.max(1)).clamp(64, bytes_per_col.max(64))
}

/// Column tile width for the dense i8/f32 batched kernels: the tile's
/// weight slab (`k` rows × tile columns × `elem_bytes`) should stay
/// L2-resident because each of the `b` batch rows re-sweeps it. Rounded
/// down to a multiple of 16 (the register micro-tile width), floor 16.
#[allow(dead_code)] // referenced only by the arch-gated SIMD backends
pub(crate) fn col_tile(k: usize, elem_bytes: usize) -> usize {
    const L2_BUDGET: usize = 192 * 1024;
    let t = L2_BUDGET / (k.max(1) * elem_bytes);
    (t & !15).max(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One test owns every mode write in this binary (two tests writing
    /// the process-global mode concurrently would race each other's
    /// asserts; sibling tests merely *reading* dispatch are safe because
    /// all backends are bit-identical).
    #[test]
    fn mode_forcing_resolves_and_degrades_correctly() {
        set_simd_mode(SimdMode::Scalar);
        assert_eq!(active_backend(), Backend::Scalar);
        assert_eq!(simd_mode(), SimdMode::Scalar);

        // Forcing a backend this machine lacks must degrade to scalar
        // (at most one of AVX2/NEON exists on any one machine).
        if !avx2_available() {
            set_simd_mode(SimdMode::Avx2);
            assert_eq!(active_backend(), Backend::Scalar);
        }
        if !cfg!(target_arch = "aarch64") {
            set_simd_mode(SimdMode::Neon);
            assert_eq!(active_backend(), Backend::Scalar);
        }

        set_simd_mode(SimdMode::Auto);
        let auto = active_backend();
        assert!(available_modes().contains(&SimdMode::Scalar));
        // Auto must resolve to something this CPU can honor.
        let ok = match auto {
            Backend::Scalar => true,
            Backend::Avx2 => avx2_available(),
            Backend::Neon => cfg!(target_arch = "aarch64"),
        };
        assert!(ok, "auto-detected backend must be available: {auto:?}");
    }

    #[test]
    fn blocking_helpers_stay_in_sane_ranges() {
        assert!(byte_block(4096, 64) >= 64);
        assert!(byte_block(4096, 64 * 1024 * 1024) == 64, "huge rows clamp to the floor");
        assert_eq!(byte_block(8, 64) % 8, 0 % 8); // tiny columns: one block
        assert!(byte_block(8, 64) >= 8, "block covers the whole column");
        assert_eq!(col_tile(4096, 1) % 16, 0);
        assert!(col_tile(1 << 30, 4) == 16, "floor is one micro-tile");
    }
}
