//! AVX2 inner loops for the batched GEMM engines and the GEMV LUT walks.
//!
//! Design notes (see `docs/performance.md` for the full story):
//!
//! * **LUT/ternary** — the per-column table walk is turned into 8-wide
//!   `vpgatherdd` lookups: 8 packed weight bytes are widened to lanes, the
//!   per-lane table indices are computed arithmetically (each byte owns a
//!   statically known group), and the i16 entries are gathered at scale 2
//!   then sign-extended in-register. Accumulation is i32, which commutes
//!   exactly, so the lane-wise reassociation is bit-identical to the
//!   scalar oracle. Columns are processed in byte *blocks* sized so the
//!   table slab a block touches (across all batch rows) stays L2-resident
//!   while the packed weight bytes stream through once.
//! * **i8/f32** — classic register blocking: for each batch row, a
//!   16-column micro-tile of accumulators lives in two ymm registers
//!   across the whole k sweep; weight rows are streamed 16 columns at a
//!   time. Column tiles are sized so a tile's weight slab stays in L2
//!   across the `b` row sweeps. Per output element the additions happen
//!   in ascending-k order with the oracle's exact skip-zero predicate, so
//!   the f32 kernel (no FMA, no reassociation) is bit-identical too.
//! * **Prefetch** — the weight-stationary stream is explicitly prefetched
//!   one step ahead (`prefetcht0`); addresses are formed with
//!   `wrapping_add` so the one-past-the-end hints stay defined behavior.
//!
//! Every function here requires AVX2; the dispatcher
//! ([`super::active_backend`]) only routes here after
//! `is_x86_feature_detected!("avx2")`.

use core::arch::x86_64::*;

use crate::gemm::lut::Luts;
use crate::gemm::TernaryLuts;
use crate::quant::{PackedBits, PackedTernary};

use super::{byte_block, col_tile};

/// Horizontal sum of 8 i32 lanes (exact: i32 addition commutes).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum_epi32(v: __m256i) -> i32 {
    let s = _mm_add_epi32(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b0100_1110>(s));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0b1011_0001>(s));
    _mm_cvtsi128_si32(s)
}

/// AVX2 path for [`crate::gemm::batched::lut_gemm_into`]'s per-chunk work
/// (`b == luts.len()` rows; `chunk` is the `[cols, b]` accumulator slab
/// for columns `col0..col0 + chunk.len()/b`).
///
/// # Safety
///
/// Requires AVX2. Caller must guarantee `chunk.len()` is a multiple of
/// `luts.len()`, the column range lies within `w`, and every
/// `luts[r].n_groups >= w.bytes_per_col * 2` (the same bound the scalar
/// oracle asserts) so all gathered indices land inside `tables`.
#[target_feature(enable = "avx2")]
pub unsafe fn lut_cols(luts: &[Luts], w: &PackedBits, col0: usize, chunk: &mut [i32]) {
    let b = luts.len();
    let cols = chunk.len() / b;
    let bpc = w.bytes_per_col;
    chunk.fill(0);
    if bpc == 0 {
        return;
    }
    // Two 16-entry i16 tables per column byte per row.
    let block = byte_block(bpc, 64 * b);
    let lane = _mm256_setr_epi32(0, 32, 64, 96, 128, 160, 192, 224);
    let nib = _mm256_set1_epi32(0xF);
    let mut b0 = 0usize;
    while b0 < bpc {
        let b1 = (b0 + block).min(bpc);
        // The final column byte always goes through the scalar tail: its
        // hi-nibble gather would otherwise read 2 bytes past `tables`.
        let vec_end = b1.min(bpc - 1);
        for cj in 0..cols {
            let j = col0 + cj;
            let colp = w.bytes.as_ptr().add(j * bpc);
            if cj + 1 < cols {
                let nxt = w.bytes.as_ptr().wrapping_add((j + 1) * bpc + b0);
                _mm_prefetch::<_MM_HINT_T0>(nxt as *const i8);
                _mm_prefetch::<_MM_HINT_T0>(nxt.wrapping_add(64) as *const i8);
            }
            for (r, lut) in luts.iter().enumerate() {
                let tab = lut.tables.as_ptr();
                let mut acc = _mm256_setzero_si256();
                let mut sum = 0i32;
                let mut bi = b0;
                while bi + 8 <= vec_end {
                    let bytes =
                        _mm256_cvtepu8_epi32(_mm_loadl_epi64(colp.add(bi) as *const __m128i));
                    let lo = _mm256_and_si256(bytes, nib);
                    let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(bytes), nib);
                    // Byte bi+l covers groups 2(bi+l) and 2(bi+l)+1, so the
                    // table element indices are 32(bi+l)+lo and 32(bi+l)+16+hi.
                    let base = _mm256_add_epi32(_mm256_set1_epi32((bi * 32) as i32), lane);
                    let ilo = _mm256_add_epi32(base, lo);
                    let ihi = _mm256_add_epi32(_mm256_add_epi32(base, _mm256_set1_epi32(16)), hi);
                    // Scale 2: indices are i16 element offsets into `tables`.
                    let glo = _mm256_i32gather_epi32::<2>(tab as *const i32, ilo);
                    let ghi = _mm256_i32gather_epi32::<2>(tab as *const i32, ihi);
                    let vlo = _mm256_srai_epi32::<16>(_mm256_slli_epi32::<16>(glo));
                    let vhi = _mm256_srai_epi32::<16>(_mm256_slli_epi32::<16>(ghi));
                    acc = _mm256_add_epi32(acc, _mm256_add_epi32(vlo, vhi));
                    bi += 8;
                }
                while bi < b1 {
                    let byte = *colp.add(bi) as usize;
                    let g = bi * 2;
                    sum += *tab.add(g * 16 + (byte & 0xF)) as i32
                        + *tab.add((g + 1) * 16 + (byte >> 4)) as i32;
                    bi += 1;
                }
                *chunk.get_unchecked_mut(cj * b + r) += hsum_epi32(acc) + sum;
            }
        }
        b0 = b1;
    }
}

/// AVX2 path for [`crate::gemm::batched::ternary_gemm_into`]'s per-chunk
/// work: 8-wide gathers into the 256-entry byte-indexed tables.
///
/// # Safety
///
/// Requires AVX2. Caller must guarantee `chunk.len()` is a multiple of
/// `luts.len()`, the column range lies within `w`, and every
/// `luts[r].n_groups >= w.bytes_per_col` so gathered indices stay inside
/// `tables`.
#[target_feature(enable = "avx2")]
pub unsafe fn ternary_cols(luts: &[TernaryLuts], w: &PackedTernary, col0: usize, chunk: &mut [i32]) {
    let b = luts.len();
    let cols = chunk.len() / b;
    let bpc = w.bytes_per_col;
    chunk.fill(0);
    if bpc == 0 {
        return;
    }
    // One 256-entry i16 table per column byte per row.
    let block = byte_block(bpc, 512 * b);
    let lane = _mm256_setr_epi32(0, 256, 512, 768, 1024, 1280, 1536, 1792);
    let mut b0 = 0usize;
    while b0 < bpc {
        let b1 = (b0 + block).min(bpc);
        // Final byte scalar: a byte of 0xFF there would gather 2 bytes
        // past the end of `tables`.
        let vec_end = b1.min(bpc - 1);
        for cj in 0..cols {
            let j = col0 + cj;
            let colp = w.bytes.as_ptr().add(j * bpc);
            if cj + 1 < cols {
                let nxt = w.bytes.as_ptr().wrapping_add((j + 1) * bpc + b0);
                _mm_prefetch::<_MM_HINT_T0>(nxt as *const i8);
                _mm_prefetch::<_MM_HINT_T0>(nxt.wrapping_add(64) as *const i8);
            }
            for (r, lut) in luts.iter().enumerate() {
                let tab = lut.tables.as_ptr();
                let mut acc = _mm256_setzero_si256();
                let mut sum = 0i32;
                let mut bi = b0;
                while bi + 8 <= vec_end {
                    let bytes =
                        _mm256_cvtepu8_epi32(_mm_loadl_epi64(colp.add(bi) as *const __m128i));
                    let base = _mm256_add_epi32(_mm256_set1_epi32((bi * 256) as i32), lane);
                    let idx = _mm256_add_epi32(base, bytes);
                    let g = _mm256_i32gather_epi32::<2>(tab as *const i32, idx);
                    let v = _mm256_srai_epi32::<16>(_mm256_slli_epi32::<16>(g));
                    acc = _mm256_add_epi32(acc, v);
                    bi += 8;
                }
                while bi < b1 {
                    let byte = *colp.add(bi) as usize;
                    sum += *tab.add(bi * 256 + byte) as i32;
                    bi += 1;
                }
                *chunk.get_unchecked_mut(cj * b + r) += hsum_epi32(acc) + sum;
            }
        }
        b0 = b1;
    }
}

/// AVX2 path for [`crate::gemm::batched::i8_gemm_batch_into`]'s per-chunk
/// work: per batch row, a 16-column accumulator micro-tile lives in two
/// ymm registers across the whole k sweep.
///
/// # Safety
///
/// Requires AVX2. Caller must guarantee `xs.len() >= b*k`,
/// `w.len() == k*n`, `chunk.len()` a multiple of `b`, and the chunk's
/// column range `col0..col0 + chunk.len()/b` within `n`.
#[target_feature(enable = "avx2")]
pub unsafe fn i8_cols(
    xs: &[i8],
    w: &[i8],
    b: usize,
    k: usize,
    n: usize,
    col0: usize,
    chunk: &mut [i32],
) {
    let cols = chunk.len() / b;
    chunk.fill(0);
    let cols16 = cols & !15;
    let tile = col_tile(k, 1);
    let mut j0 = 0usize;
    while j0 < cols16 {
        let j1 = (j0 + tile).min(cols16);
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut jm = j0;
            while jm < j1 {
                let mut acc0 = _mm256_setzero_si256();
                let mut acc1 = _mm256_setzero_si256();
                for kk in 0..k {
                    let xv = *xrow.add(kk);
                    if xv == 0 {
                        // Exact for integers (+0 is the identity); matches
                        // the oracle's skip-zero predicate.
                        continue;
                    }
                    let wp = w.as_ptr().add(kk * n + col0 + jm);
                    _mm_prefetch::<_MM_HINT_T0>(wp.wrapping_add(n) as *const i8);
                    let xb = _mm256_set1_epi32(xv as i32);
                    let w0 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(wp as *const __m128i));
                    let w1 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(wp.add(8) as *const __m128i));
                    acc0 = _mm256_add_epi32(acc0, _mm256_mullo_epi32(xb, w0));
                    acc1 = _mm256_add_epi32(acc1, _mm256_mullo_epi32(xb, w1));
                }
                let mut buf = [0i32; 16];
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc0);
                _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc1);
                for (l, &v) in buf.iter().enumerate() {
                    *chunk.get_unchecked_mut((jm + l) * b + r) = v;
                }
                jm += 16;
            }
        }
        j0 = j1;
    }
    // Remainder columns (< 16): scalar, same ascending-k order.
    for cj in cols16..cols {
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut sum = 0i32;
            for kk in 0..k {
                let xv = *xrow.add(kk);
                if xv == 0 {
                    continue;
                }
                sum += xv as i32 * *w.get_unchecked(kk * n + col0 + cj) as i32;
            }
            *chunk.get_unchecked_mut(cj * b + r) = sum;
        }
    }
}

/// AVX2 path for [`crate::gemm::batched::f32_gemm_batch_into`]'s per-chunk
/// work. Bit-identical to the scalar oracle: the reduction stays k-major
/// with the scalar-broadcast activation and the oracle's skip-zero
/// predicate; lanes are output columns, so no reassociation and no FMA
/// contraction touches any output element's addition sequence.
///
/// # Safety
///
/// Requires AVX2. Caller must guarantee `xs.len() >= b*k`,
/// `w.len() == k*n`, `chunk.len()` a multiple of `b`, and the chunk's
/// column range within `n`.
#[target_feature(enable = "avx2")]
pub unsafe fn f32_cols(
    xs: &[f32],
    w: &[f32],
    b: usize,
    k: usize,
    n: usize,
    col0: usize,
    chunk: &mut [f32],
) {
    let cols = chunk.len() / b;
    chunk.fill(0.0);
    let cols16 = cols & !15;
    let tile = col_tile(k, 4);
    let mut j0 = 0usize;
    while j0 < cols16 {
        let j1 = (j0 + tile).min(cols16);
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut jm = j0;
            while jm < j1 {
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                for kk in 0..k {
                    let xv = *xrow.add(kk);
                    if xv == 0.0 {
                        // The oracle's exact predicate (also skips -0.0).
                        continue;
                    }
                    let wp = w.as_ptr().add(kk * n + col0 + jm);
                    _mm_prefetch::<_MM_HINT_T0>(wp.wrapping_add(n) as *const i8);
                    let xb = _mm256_set1_ps(xv);
                    // mul then add, never FMA: one rounding per op exactly
                    // like the scalar `*cv += av * bv`.
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(xb, _mm256_loadu_ps(wp)));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(xb, _mm256_loadu_ps(wp.add(8))));
                }
                let mut buf = [0f32; 16];
                _mm256_storeu_ps(buf.as_mut_ptr(), acc0);
                _mm256_storeu_ps(buf.as_mut_ptr().add(8), acc1);
                for (l, &v) in buf.iter().enumerate() {
                    *chunk.get_unchecked_mut((jm + l) * b + r) = v;
                }
                jm += 16;
            }
        }
        j0 = j1;
    }
    for cj in cols16..cols {
        for r in 0..b {
            let xrow = xs.as_ptr().add(r * k);
            let mut sum = 0f32;
            for kk in 0..k {
                let xv = *xrow.add(kk);
                if xv == 0.0 {
                    continue;
                }
                sum += xv * *w.get_unchecked(kk * n + col0 + cj);
            }
            *chunk.get_unchecked_mut(cj * b + r) = sum;
        }
    }
}
