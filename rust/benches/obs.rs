//! Observability overhead bench (ISSUE 8): hot-path costs of the metric
//! primitives (histogram record, counter add, span recording, quantile
//! scrape) and the end-to-end engine cost of tracing enabled vs disabled
//! on identical request bursts. The traced/untraced median ratio lands in
//! `results/bench/obs.json` as `trace_overhead_ratio` — the acceptance
//! target is < 5% overhead; the assert here is looser (25%) so a noisy
//! CI machine doesn't flake the lane.

use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::obs::{Histogram, Registry, SpanKind, TraceShared};
use pquant::serve::{Engine, EngineOptions, GenRequest, ModelRegistry, Ticket};
use pquant::util::bench::Bencher;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "bench-obs".into(),
        variant: Variant::PQuant,
        vocab: 512,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: 32,
        n_experts: 1,
        seq_len: 64,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

/// One unit of engine work: an 8-request burst of 8 greedy tokens each.
fn burst(engine: &Engine) -> usize {
    let tickets: Vec<Ticket> = (0..8u32)
        .map(|id| {
            let prompt: Vec<u32> = (0..4).map(|i| (id + i) % 512).collect();
            engine.submit(GenRequest::greedy(prompt, 8)).expect("queue fits burst")
        })
        .collect();
    tickets.into_iter().map(|t| t.wait().tokens.len()).sum()
}

fn main() {
    let mut b = Bencher::quick();

    // --- primitives (the per-step engine hot path) ---
    let hist = Histogram::new();
    let mut x = 0.1f64;
    b.bench("histogram record", || {
        x = (x * 1.37 + 0.11) % 5000.0;
        hist.record(x);
    });
    let reg = Registry::new();
    let ctr = reg.counter_with("bench_steps_total", &[("phase", "bench")], "bench counter");
    b.bench("counter add (labeled handle)", || ctr.add(1));
    b.bench("histogram p99 scrape", || hist.quantile(99));

    let tr = TraceShared::new();
    let mut id = 0u64;
    b.bench("trace begin + 10 spans + finish", || {
        id += 1;
        let mut tb = tr.begin(id);
        let t0 = tb.now_us();
        for i in 0..10u64 {
            tb.span_since(SpanKind::BatchStep, t0, i, 1);
        }
        tb.finish(1, 10);
    });

    // --- engine bursts, tracing off vs on, same weights and geometry ---
    let model = PackedModel::random(&cfg(), 3);
    let mut medians = [0.0f64; 2];
    for (slot, (label, trace)) in
        [("untraced", false), ("traced", true)].into_iter().enumerate()
    {
        let registry = Arc::new(ModelRegistry::new());
        registry.register(label, model.clone(), None);
        let engine = Engine::start(
            &registry,
            EngineOptions {
                model: label.into(),
                max_batch: 4,
                workers: 1,
                queue_depth: 16,
                prefill_chunk: 16,
                trace,
                ..EngineOptions::default()
            },
        )
        .expect("model registered above");
        medians[slot] =
            b.bench(&format!("serve 8req x 8tok {label}"), || burst(&engine)).median();
        engine.shutdown();
    }
    let ratio = medians[1] / medians[0].max(1e-12);
    b.metric("trace_overhead_ratio", ratio);
    assert!(ratio < 1.25, "tracing overhead ratio {ratio:.3} out of bounds");
    b.write_json("obs");
}
