//! Serving throughput bench (§4.5): packed engines under the `Engine`
//! continuous batcher at matched geometry.

use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::serve::{Engine, EngineOptions, GenRequest, ModelRegistry, Ticket};
use pquant::util::bench::Bencher;

fn cfg(variant: Variant, n: usize) -> ModelConfig {
    ModelConfig {
        name: format!("bench-{}", variant.name()),
        variant,
        vocab: 512,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: if variant == Variant::PQuant { 32 } else { 0 },
        n_experts: if variant == Variant::PQuant { n } else { 1 },
        seq_len: 64,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() {
    let mut b = Bencher::quick();
    // Steady-state engine throughput: one persistent engine per variant,
    // each iteration pushes a fresh burst of requests through it.
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant-n1", Variant::PQuant, 1),
        ("pquant-n8", Variant::PQuant, 8),
    ] {
        let registry = Arc::new(ModelRegistry::new());
        registry.register(label, PackedModel::random(&cfg(variant, n), 3), None);
        let engine = Engine::start(
            &registry,
            EngineOptions {
                model: label.into(),
                max_batch: 4,
                workers: 1,
                queue_depth: 16,
                prefill_chunk: 16,
                ..EngineOptions::default()
            },
        )
        .expect("model registered above");
        b.bench(&format!("serve 8req x 8tok {label}"), || {
            let tickets: Vec<Ticket> = (0..8u32)
                .map(|id| {
                    let prompt: Vec<u32> = (0..4).map(|i| (id + i) % 512).collect();
                    engine.submit(GenRequest::greedy(prompt, 8)).expect("queue fits burst")
                })
                .collect();
            tickets.into_iter().map(|t| t.wait().tokens.len()).sum::<usize>()
        });
        engine.shutdown();
    }
    // decode-step microbench (single token, batch 1)
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant-n1", Variant::PQuant, 1),
    ] {
        let mut model = PackedModel::random(&cfg(variant, n), 4);
        let mut caches = model.new_caches(64);
        let mut pos = 0usize;
        b.bench(&format!("decode_step {label}"), || {
            if pos >= 63 {
                caches = model.new_caches(64);
                pos = 0;
            }
            let out = model.decode_step(1, pos, &mut caches);
            pos += 1;
            out
        });
    }
    b.write_json("serving");
}
