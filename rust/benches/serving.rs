//! Serving throughput bench (§4.5): packed engines under the continuous
//! batcher at matched geometry.

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::serve::{load_test, ServeOptions};
use pquant::util::bench::Bencher;

fn cfg(variant: Variant, n: usize) -> ModelConfig {
    ModelConfig {
        name: format!("bench-{}", variant.name()),
        variant,
        vocab: 512,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: if variant == Variant::PQuant { 32 } else { 0 },
        n_experts: if variant == Variant::PQuant { n } else { 1 },
        seq_len: 64,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() {
    let mut b = Bencher::quick();
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant-n1", Variant::PQuant, 1),
        ("pquant-n8", Variant::PQuant, 8),
    ] {
        b.bench(&format!("serve 8req x 8tok {label}"), || {
            let model = PackedModel::random(&cfg(variant, n), 3);
            load_test(vec![model], 8, 4, 8, &ServeOptions { max_batch: 4, workers: 1 })
        });
    }
    // decode-step microbench (single token, batch 1)
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant-n1", Variant::PQuant, 1),
    ] {
        let mut model = PackedModel::random(&cfg(variant, n), 4);
        let mut caches = model.new_caches(64);
        let mut pos = 0usize;
        b.bench(&format!("decode_step {label}"), || {
            if pos >= 63 {
                caches = model.new_caches(64);
                pos = 0;
            }
            let out = model.decode_step(1, pos, &mut caches);
            pos += 1;
            out
        });
    }
    b.write_json("serving");
}
