//! AOT train-step bench: PJRT execution + host state threading overhead
//! (the L3 part of the training hot path; Table 8's per-step cost).
//! Requires `make artifacts`.

use pquant::runtime::{load_artifact, Runtime, TrainState};
use pquant::util::bench::Bencher;

fn main() {
    let mut b = Bencher::quick();
    let runtime = Runtime::cpu().expect("PJRT CPU client");
    for config in ["nano-pquant", "micro-pquant", "micro-pquant-n8"] {
        let Ok(art) = load_artifact(config) else {
            eprintln!("[skip] {config}: run `make artifacts`");
            continue;
        };
        let step = runtime.compile(&art, "train_step").expect("compile");
        let mut state = TrainState::initial(&art).expect("init");
        let n_tok = step.spec.inputs.last().unwrap().element_count();
        let tokens: Vec<i32> =
            (0..n_tok).map(|i| (i % art.manifest.config.vocab) as i32).collect();
        // warm once (first execution includes lazy init)
        state.step(&step, &tokens, 1e-3, 0.1).unwrap();
        b.bench(&format!("train_step {config}"), || {
            state.step(&step, &tokens, 1e-3, 0.1).unwrap()
        });
        // state-threading overhead: fwd-only for comparison
        let fwd = runtime.compile(&art, "fwd").expect("compile fwd");
        let seq = art.manifest.seq_len;
        let toks: Vec<i32> = (0..seq).map(|i| (i % 100) as i32).collect();
        b.bench(&format!("fwd_b1      {config}"), || {
            state.forward(&fwd, &toks).unwrap()
        });
    }
    b.write_json("train_step");
}
