//! Quantizer + packing benches: the offline weight-conversion path and the
//! per-token activation quantization that sits on the decode hot path.

use pquant::quant;
use pquant::util::bench::Bencher;
use pquant::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(2);

    let w: Vec<f32> = rng.normal_vec(4096 * 4096);
    b.bench("binarize 4096x4096", || quant::binarize(&w));
    b.bench("ternarize 4096x4096", || quant::ternarize(&w));
    b.bench("quantize_i8 4096x4096", || quant::quantize_i8(&w));

    let bin = quant::binarize(&w);
    b.bench("pack_signs 4096x4096", || quant::pack_signs(&bin.signs, 4096, 4096));
    let tern = quant::ternarize(&w);
    b.bench("pack_ternary 4096x4096", || quant::pack_ternary(&tern.vals, 4096, 4096));

    // per-token activation quantization (hot path, d=4096)
    let x: Vec<f32> = rng.normal_vec(4096);
    b.bench("quantize_i8_rows 1x4096 (per token)", || {
        quant::quantize_i8_rows(&x, 1, 4096)
    });

    // group/channel-wise ablation quantizers
    let wg: Vec<f32> = rng.normal_vec(4096 * 256);
    b.bench("binarize_channelwise 4096x256", || {
        quant::binarize_channelwise(&wg, 4096, 256)
    });
    b.bench("binarize_groupwise g=64 4096x256", || {
        quant::binarize_groupwise(&wg, 4096, 256, 64)
    });
    b.write_json("quant_pack");
}
