//! Paged KV-cache bench: block alloc/free cycles, append throughput of
//! paged vs contiguous layouts, shared- vs unshared-prefix prefill
//! through the packed model (the compute the prefix map saves), and the
//! storage-mode capacity comparison (sequences admitted per MB, f32 vs
//! int8 under the same block budget).

use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::{KvCache, PackedModel};
use pquant::kvcache::{BlockPool, KvPoolOptions, KvStorageMode, KvStore, PagedSeq, PrefixTag};
use pquant::util::bench::Bencher;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "bench-kvcache".into(),
        variant: Variant::PQuant,
        vocab: 256,
        d_model: 128,
        n_layers: 4,
        n_heads: 8,
        d_ff: 352,
        r: 32,
        n_experts: 2,
        seq_len: 128,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() {
    let mut b = Bencher::quick();
    let cfg = cfg();
    let pool = Arc::new(BlockPool::new(
        KvPoolOptions { n_blocks: 4096, block_size: 16, ..Default::default() },
        cfg.n_layers,
        cfg.d_model,
    ));

    // Admission + page-table construction + release, no decode.
    b.bench("pool admit/release 128-token seq", || {
        let adm = pool.admit(&[], 128, PrefixTag::default()).expect("pool sized for bench");
        PagedSeq::new(&pool, adm)
    });

    // Append throughput: one 128-token sequence, all layers.
    let row = vec![0.5f32; cfg.d_model];
    b.bench("paged append 128 tok x 4 layers", || {
        let adm = pool.admit(&[], 128, PrefixTag::default()).expect("pool sized for bench");
        let mut seq = PagedSeq::new(&pool, adm);
        for _ in 0..128 {
            for l in 0..cfg.n_layers {
                seq.layer(l).push(&row, &row).expect("reserved up front");
            }
        }
        seq.len()
    });
    b.bench("contiguous append 128 tok x 4 layers", || {
        let mut caches: Vec<KvCache> =
            (0..cfg.n_layers).map(|_| KvCache::new(128, cfg.d_model)).collect();
        for _ in 0..128 {
            for c in caches.iter_mut() {
                c.push(&row, &row).expect("sized up front");
            }
        }
        caches[0].len
    });

    // Prefill with and without a registered prefix: the shared path skips
    // the covered positions entirely (attention compute, not just bytes).
    let mut model = PackedModel::random(&cfg, 7);
    let prompt: Vec<u32> = (0..64u32).map(|i| (i * 5) % 256).collect();
    let tag = PrefixTag(1, 1);
    let total = prompt.len() + 16;
    {
        // Register the prompt's prefixes once, outside the timed region.
        let adm = pool.admit(&prompt, total, tag).expect("pool sized for bench");
        let mut seq = PagedSeq::new(&pool, adm);
        for (pos, &t) in prompt.iter().enumerate() {
            model.decode_step_paged(t, pos, &mut seq).expect("reserved up front");
        }
        pool.register_prefix(&prompt, &mut seq);
    }
    let fresh_tag = PrefixTag(2, 2); // never registered: full prefill
    b.bench("prefill 64-token prompt, unshared", || {
        let adm = pool.admit(&prompt, total, fresh_tag).expect("pool sized for bench");
        let mut seq = PagedSeq::new(&pool, adm);
        let mut logits = Vec::new();
        for pos in seq.len()..prompt.len() {
            logits = model.decode_step_paged(prompt[pos], pos, &mut seq).expect("reserved");
        }
        logits
    });
    b.bench("prefill 64-token prompt, shared prefix", || {
        let adm = pool.admit(&prompt, total, tag).expect("pool sized for bench");
        let mut seq = PagedSeq::new(&pool, adm);
        assert!(!seq.is_empty(), "prefix must actually hit");
        let mut logits = Vec::new();
        for pos in seq.len()..prompt.len() {
            logits = model.decode_step_paged(prompt[pos], pos, &mut seq).expect("reserved");
        }
        logits
    });

    let s = pool.stats();
    println!(
        "  pool after bench: hit rate {:.2}, cow {}, evicted {}, prefixes {}",
        s.shared_hit_rate, s.cow_copies, s.evicted_blocks, s.registered_prefixes
    );

    // Storage-mode capacity: same block budget (same bytes), admit
    // 128-token sequences until the pool refuses. Int8 packs 4x the rows
    // per block, so it must admit >= 4x the sequences of f32 — that ratio
    // is the whole point of the quantized tier, so the bench asserts it.
    let seq_tokens = 128;
    let mut admitted = Vec::new();
    for mode in [KvStorageMode::F32, KvStorageMode::Int8] {
        let opts = KvPoolOptions { n_blocks: 1024, block_size: 16, mode };
        let cap_pool = Arc::new(BlockPool::new(opts, cfg.n_layers, cfg.d_model));
        let mb = cap_pool.stats().capacity_bytes as f64 / (1024.0 * 1024.0);
        let mut live = Vec::new();
        while let Ok(adm) = cap_pool.admit(&[], seq_tokens, PrefixTag::default()) {
            live.push(PagedSeq::new(&cap_pool, adm));
        }
        let n = live.len();
        b.metric(&format!("admit capacity {mode} seqs@{seq_tokens}tok"), n as f64);
        b.metric(&format!("admit capacity {mode} seqs/MB"), n as f64 / mb);
        admitted.push(n);
    }
    let ratio = admitted[1] as f64 / admitted[0] as f64;
    b.metric("admit capacity int8/f32 ratio", ratio);
    assert!(
        ratio >= 4.0,
        "int8 must admit >= 4x the sequences of f32 on the same budget, got {}/{}",
        admitted[1],
        admitted[0]
    );

    b.write_json("kvcache");
}
