//! `.pqm` artifact bench: save/load wall time and bytes/s for each
//! [`Variant`] at the same geometry as the serving bench, so artifact
//! encode/decode cost can be read next to serving throughput
//! (results/bench/serving.json vs results/bench/model_load.json).

use pquant::artifact;
use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::util::bench::Bencher;

fn cfg(variant: Variant, n: usize) -> ModelConfig {
    ModelConfig {
        name: format!("bench-{}-n{n}", variant.name()),
        variant,
        vocab: 512,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: if variant == Variant::PQuant { 32 } else { 0 },
        n_experts: if variant == Variant::PQuant { n } else { 1 },
        seq_len: 64,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn mb_per_s(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0) / secs.max(1e-12)
}

fn main() {
    let mut b = Bencher::quick();
    for (label, variant, n) in [
        ("fp16", Variant::Fp16, 1),
        ("bitnet", Variant::BitNet, 1),
        ("bitnet1.58", Variant::BitNet158, 1),
        ("pquant-n1", Variant::PQuant, 1),
        ("pquant-n8", Variant::PQuant, 8),
    ] {
        let model = PackedModel::random(&cfg(variant, n), 7);
        let bytes = artifact::save_pqm_bytes(&model, None);
        let size = bytes.len();

        let save_s = b
            .bench(&format!("pqm save {label} ({:.1} MiB)", size as f64 / (1024.0 * 1024.0)), || {
                artifact::save_pqm_bytes(&model, None)
            })
            .median();
        let load_s = b
            .bench(&format!("pqm load {label}"), || {
                artifact::load_pqm_bytes(&bytes).expect("bench artifact is valid")
            })
            .median();
        println!(
            "  {label}: save {:.0} MiB/s, load {:.0} MiB/s",
            mb_per_s(size, save_s),
            mb_per_s(size, load_s)
        );
    }

    // Disk round-trip (write + read + CRC + decode) for the pQuant variant.
    let model = PackedModel::random(&cfg(Variant::PQuant, 8), 11);
    let path = std::env::temp_dir().join(format!("pqm_bench_{}.pqm", std::process::id()));
    b.bench("pqm disk round-trip pquant-n8", || {
        artifact::save_pqm(&model, None, &path).expect("save");
        artifact::load_pqm(&path).expect("load")
    });
    std::fs::remove_file(&path).ok();

    b.write_json("model_load");
}
