//! Batched decode throughput: aggregate tokens/sec of the fused
//! weight-stationary batch step at batch 1 / 4 / 16 on a small packed
//! model. The acceptance bar for the batch path is batch-16 aggregate
//! throughput ≥ 3× batch-1 (each packed weight column is read once per
//! step instead of once per request). Run with
//! `cargo bench --bench decode_batch`; writes
//! `results/bench/decode_batch.json` including the batch-16 / batch-1
//! ratio.

use pquant::config::{ModelConfig, Variant};
use pquant::gemm::{set_simd_mode, SimdMode};
use pquant::infer::{BatchKv, KvCache, PackedModel, Scratch, SeqStep};
use pquant::util::bench::Bencher;
use pquant::util::json::{arr, num, obj};

fn small_cfg() -> ModelConfig {
    ModelConfig {
        name: "decode-batch-bench".into(),
        variant: Variant::PQuant,
        vocab: 2048,
        d_model: 256,
        n_layers: 2,
        n_heads: 4,
        d_ff: 704,
        r: 32,
        n_experts: 2,
        seq_len: 256,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() {
    let cfg = small_cfg();
    let mut model = PackedModel::random(&cfg, 7);
    let mut b = Bencher::quick();
    let cap = 256usize;
    let mut tps: Vec<(usize, f64)> = Vec::new();

    for &bs in &[1usize, 4, 16] {
        let mut caches: Vec<Vec<KvCache>> = (0..bs).map(|_| model.new_caches(cap)).collect();
        let mut scratch = Scratch::new();
        let mut pos = 0usize;
        let vocab = cfg.vocab;
        let stats = b.bench(&format!("decode_step_batch b={bs:<2} (aggregate step)"), || {
            if pos >= cap {
                for c in caches.iter_mut() {
                    for l in c.iter_mut() {
                        l.reset();
                    }
                }
                pos = 0;
            }
            let toks: Vec<u32> = (0..bs).map(|si| ((pos * 7 + si) % vocab) as u32).collect();
            let mut steps: Vec<SeqStep> = caches
                .iter_mut()
                .zip(&toks)
                .map(|(c, t)| {
                    SeqStep::new(std::slice::from_ref(t), pos, BatchKv::Contig(&mut c[..]), true)
                })
                .collect();
            model.decode_step_batch(&mut steps, &mut scratch);
            pos += 1;
            scratch.logits_row(0)[0]
        });
        tps.push((bs, bs as f64 / stats.median()));
    }

    // Batch-16 again with the kernels forced to the scalar oracle: the
    // end-to-end decode-step speedup attributable to gemm::simd dispatch.
    set_simd_mode(SimdMode::Scalar);
    let bs = 16usize;
    let scalar_tps = {
        let mut caches: Vec<Vec<KvCache>> = (0..bs).map(|_| model.new_caches(cap)).collect();
        let mut scratch = Scratch::new();
        let mut pos = 0usize;
        let vocab = cfg.vocab;
        let stats = b.bench("decode_step_batch b=16 (forced scalar)", || {
            if pos >= cap {
                for c in caches.iter_mut() {
                    for l in c.iter_mut() {
                        l.reset();
                    }
                }
                pos = 0;
            }
            let toks: Vec<u32> = (0..bs).map(|si| ((pos * 7 + si) % vocab) as u32).collect();
            let mut steps: Vec<SeqStep> = caches
                .iter_mut()
                .zip(&toks)
                .map(|(c, t)| {
                    SeqStep::new(std::slice::from_ref(t), pos, BatchKv::Contig(&mut c[..]), true)
                })
                .collect();
            model.decode_step_batch(&mut steps, &mut scratch);
            pos += 1;
            scratch.logits_row(0)[0]
        });
        bs as f64 / stats.median()
    };
    set_simd_mode(SimdMode::Auto);

    for &(bs, t) in &tps {
        println!("batch {bs:>2}: {t:.0} tokens/s aggregate");
    }
    let ratio = tps.last().unwrap().1 / tps[0].1;
    println!("batch-16 vs batch-1 aggregate throughput: {ratio:.2}x");
    let simd_ratio = tps.last().unwrap().1 / scalar_tps;
    println!("batch-16 simd vs forced-scalar throughput: {simd_ratio:.2}x");

    let entries: Vec<_> = tps
        .iter()
        .map(|&(bs, t)| obj(vec![("batch", num(bs as f64)), ("tokens_per_sec", num(t))]))
        .collect();
    let payload = obj(vec![
        ("batches", arr(entries)),
        ("batch16_vs_batch1_ratio", num(ratio)),
        ("batch16_scalar_tokens_per_sec", num(scalar_tps)),
        ("scalar_vs_simd_ratio", num(simd_ratio)),
    ]);
    std::fs::create_dir_all("results/bench").ok();
    std::fs::write("results/bench/decode_batch.json", payload.to_string_pretty()).ok();
    println!("[bench] wrote results/bench/decode_batch.json");
    b.write_json("decode_batch_raw");
}
