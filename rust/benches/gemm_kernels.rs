//! Figure 8 kernel benches: the four matmul engines at the paper's 7B
//! linear-layer shapes (d=4096 GEMV, the edge decode regime) and at the
//! testbed's micro shapes, plus a scalar-vs-SIMD A/B of the batched
//! engines (recorded as `scalar_vs_simd_ratio/...` metrics — see
//! `docs/performance.md`).  Run with `cargo bench --bench gemm_kernels`;
//! writes `results/bench/gemm_kernels.json`.

use pquant::gemm::{
    build_luts, build_ternary_luts, f32_gemm_batch_into, f32_gemv, i8_gemm_batch_into, i8_gemv,
    lut_gemm_into, lut_gemv, lut_gemv_into, set_simd_mode, simd, ternary_gemm_into, ternary_gemv,
    SimdMode,
};
use pquant::quant::{pack_signs, pack_ternary};
use pquant::util::bench::Bencher;
use pquant::util::rng::Rng;

/// Time `f` under forced-scalar then auto dispatch and record the ratio.
fn ab<T, F: FnMut() -> T>(b: &mut Bencher, name: &str, mut f: F) {
    set_simd_mode(SimdMode::Scalar);
    let t_scalar = b.bench(&format!("{name} [scalar]"), &mut f).median();
    set_simd_mode(SimdMode::Auto);
    let t_auto = b.bench(&format!("{name} [auto]"), &mut f).median();
    b.metric(&format!("scalar_vs_simd_ratio/{name}"), t_scalar / t_auto);
}

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(1);

    for &(k, n, label) in &[
        (4096usize, 4096usize, "7B attn proj"),
        (4096, 11008, "7B ffn up"),
        (256, 704, "micro ffn up"),
    ] {
        let x_f: Vec<f32> = rng.normal_vec(k);
        let x_q: Vec<i8> = x_f.iter().map(|v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();
        let w_f: Vec<f32> = rng.normal_vec(k * n);
        let signs: Vec<bool> = w_f.iter().map(|&v| v >= 0.0).collect();
        let w_packed = pack_signs(&signs, k, n);
        let tern: Vec<i8> = w_f.iter().map(|&v| (v * 1.2).round().clamp(-1.0, 1.0) as i8).collect();
        let w_tern = pack_ternary(&tern, k, n);
        let w_i8: Vec<i8> = w_f.iter().map(|&v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();

        b.bench(&format!("f32_gemv       {label} {k}x{n}"), || f32_gemv(&x_f, &w_f, k, n));
        b.bench(&format!("i8_gemv        {label} {k}x{n}"), || i8_gemv(&x_q, &w_i8, k, n));
        b.bench(&format!("ternary_gemv   {label} {k}x{n}"), || ternary_gemv(&x_q, &w_tern));
        b.bench(&format!("lut_build      {label} k={k}"), || build_luts(&x_q, k));
        let luts = build_luts(&x_q, k);
        b.bench(&format!("lut_gemv(W1A8) {label} {k}x{n}"), || lut_gemv(&luts, &w_packed));
        b.bench(&format!("lut_build+gemv {label} {k}x{n}"), || {
            let l = build_luts(&x_q, k);
            lut_gemv(&l, &w_packed)
        });
    }

    // Scalar-vs-SIMD A/B on the batched engines and the GEMV LUT walk.
    // Auto resolves through gemm::simd (AVX2/NEON when the CPU has it);
    // outputs are bit-identical in both lanes, so the ratio is a pure
    // kernel speedup.
    println!("auto dispatch resolves to: {:?}", simd::active_backend());
    for &(k, n, bs, label) in
        &[(1024usize, 2816usize, 16usize, "mid"), (256, 704, 16, "micro")]
    {
        let w_f: Vec<f32> = rng.normal_vec(k * n);
        let signs: Vec<bool> = w_f.iter().map(|&v| v >= 0.0).collect();
        let w_packed = pack_signs(&signs, k, n);
        let tern: Vec<i8> = w_f.iter().map(|&v| (v * 1.2).round().clamp(-1.0, 1.0) as i8).collect();
        let w_tern = pack_ternary(&tern, k, n);
        let w_i8: Vec<i8> = w_f.iter().map(|&v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();
        let x_f: Vec<f32> = rng.normal_vec(bs * k);
        let x_q: Vec<i8> =
            x_f.iter().map(|v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();
        let luts: Vec<_> = (0..bs).map(|r| build_luts(&x_q[r * k..(r + 1) * k], k)).collect();
        let tluts: Vec<_> =
            (0..bs).map(|r| build_ternary_luts(&x_q[r * k..(r + 1) * k], k)).collect();

        let mut yi = vec![0i32; n * bs];
        let mut yf = vec![0f32; n * bs];
        let mut y1 = vec![0i32; n];

        ab(&mut b, &format!("lut_gemm {label} {k}x{n} b={bs}"), || {
            lut_gemm_into(&luts, &w_packed, &mut yi);
            yi[0]
        });
        ab(&mut b, &format!("ternary_gemm {label} {k}x{n} b={bs}"), || {
            ternary_gemm_into(&tluts, &w_tern, &mut yi);
            yi[0]
        });
        ab(&mut b, &format!("i8_gemm_batch {label} {k}x{n} b={bs}"), || {
            i8_gemm_batch_into(&x_q, &w_i8, bs, k, n, &mut yi);
            yi[0]
        });
        ab(&mut b, &format!("f32_gemm_batch {label} {k}x{n} b={bs}"), || {
            f32_gemm_batch_into(&x_f, &w_f, bs, k, n, &mut yf);
            yf[0]
        });
        ab(&mut b, &format!("lut_gemv {label} {k}x{n}"), || {
            lut_gemv_into(&luts[0], &w_packed, &mut y1);
            y1[0]
        });
    }
    set_simd_mode(SimdMode::Auto);
    b.write_json("gemm_kernels");
}
