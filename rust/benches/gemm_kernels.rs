//! Figure 8 kernel benches: the four matmul engines at the paper's 7B
//! linear-layer shapes (d=4096 GEMV, the edge decode regime) and at the
//! testbed's micro shapes.  Run with `cargo bench --bench gemm_kernels`.

use pquant::gemm::{build_luts, f32_gemv, i8_gemv, lut_gemv, ternary_gemv};
use pquant::quant::{pack_signs, pack_ternary};
use pquant::util::bench::Bencher;
use pquant::util::rng::Rng;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Rng::new(1);

    for &(k, n, label) in &[
        (4096usize, 4096usize, "7B attn proj"),
        (4096, 11008, "7B ffn up"),
        (256, 704, "micro ffn up"),
    ] {
        let x_f: Vec<f32> = rng.normal_vec(k);
        let x_q: Vec<i8> = x_f.iter().map(|v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();
        let w_f: Vec<f32> = rng.normal_vec(k * n);
        let signs: Vec<bool> = w_f.iter().map(|&v| v >= 0.0).collect();
        let w_packed = pack_signs(&signs, k, n);
        let tern: Vec<i8> = w_f.iter().map(|&v| (v * 1.2).round().clamp(-1.0, 1.0) as i8).collect();
        let w_tern = pack_ternary(&tern, k, n);
        let w_i8: Vec<i8> = w_f.iter().map(|&v| (v * 40.0).clamp(-127.0, 127.0) as i8).collect();

        b.bench(&format!("f32_gemv       {label} {k}x{n}"), || f32_gemv(&x_f, &w_f, k, n));
        b.bench(&format!("i8_gemv        {label} {k}x{n}"), || i8_gemv(&x_q, &w_i8, k, n));
        b.bench(&format!("ternary_gemv   {label} {k}x{n}"), || ternary_gemv(&x_q, &w_tern));
        b.bench(&format!("lut_build      {label} k={k}"), || build_luts(&x_q, k));
        let luts = build_luts(&x_q, k);
        b.bench(&format!("lut_gemv(W1A8) {label} {k}x{n}"), || lut_gemv(&luts, &w_packed));
        b.bench(&format!("lut_build+gemv {label} {k}x{n}"), || {
            let l = build_luts(&x_q, k);
            lut_gemv(&l, &w_packed)
        });
    }
    b.write_json("gemm_kernels");
}
