//! Trace-driven serving bench: replay a short fixed-seed bursty trace
//! against a persistent engine and publish the SLO report (goodput,
//! per-tier TTFT/TPOT p50/p95/p99, 429/503 rates) to
//! `results/bench/loadgen.json`. The schedule is seeded — identical
//! across runs and commits — so the report is comparable history.

use std::sync::Arc;

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::serve::loadgen::{self, Target, TraceConfig};
use pquant::serve::{Engine, EngineOptions, ModelRegistry};

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "bench-loadgen".into(),
        variant: Variant::PQuant,
        vocab: 512,
        d_model: 256,
        n_layers: 4,
        n_heads: 8,
        d_ff: 704,
        r: 32,
        n_experts: 1,
        seq_len: 64,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("bench", PackedModel::random(&bench_cfg(), 3), None);
    let engine = Engine::start(
        &registry,
        EngineOptions {
            model: "bench".into(),
            max_batch: 4,
            workers: 1,
            queue_depth: 64,
            ..EngineOptions::default()
        },
    )
    .expect("model registered above");

    // Fixed seed, bursty mix, ~2 simulated seconds of arrivals: small
    // enough for CI's bench lane, bursty enough to exercise backpressure.
    let cfg = TraceConfig {
        seed: 0xBEEF,
        n_requests: 48,
        rate: 60.0,
        burst_factor: 5.0,
        prompt_lens: vec![(4, 0.5), (12, 0.3), (24, 0.2)],
        output_lens: vec![(8, 0.6), (16, 0.3), (32, 0.1)],
        shared_prefix_len: 16,
        vocab: 512,
        ..TraceConfig::default()
    };
    let report = loadgen::run(Target::Engine(&engine), &cfg).expect("in-process replay");
    let metrics = engine.shutdown();

    println!(
        "loadgen: {} req in {:.2}s | {:.1} tokens/s | goodput {:.0}% | {} x429 {} x503",
        report.submitted,
        report.wall.as_secs_f64(),
        report.throughput(),
        report.goodput() * 100.0,
        report.retries_429,
        report.retries_503,
    );
    for t in &report.tiers {
        println!(
            "  {:12} n {:>3}  goodput {:>3.0}%  ttft p50/p95/p99 {:.1}/{:.1}/{:.1} ms  \
             tpot p50/p95/p99 {:.1}/{:.1}/{:.1} ms",
            t.name,
            t.n,
            t.goodput * 100.0,
            t.ttft.p50,
            t.ttft.p95,
            t.ttft.p99,
            t.tpot.p50,
            t.tpot.p95,
            t.tpot.p99,
        );
    }
    let server_tpot = metrics.tpot_percentiles();
    println!(
        "server-side tpot p50 {:.2} ms over {} samples",
        server_tpot.p50, server_tpot.n
    );
    report
        .write(std::path::Path::new("results/bench/loadgen.json"))
        .expect("writing results/bench/loadgen.json");
    println!("wrote results/bench/loadgen.json");
}
