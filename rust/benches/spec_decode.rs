//! Speculative decode throughput: spec-vs-plain tokens/sec on a small
//! packed target with (a) a half-depth/half-width draft and (b) a perfect
//! self-draft (the acceptance upper bound). Records acceptance rate, mean
//! accepted tokens per verify step, and the spec/plain throughput ratio
//! into `results/bench/spec_decode.json`. Run with
//! `cargo bench --bench spec_decode`.

use pquant::config::{ModelConfig, Variant};
use pquant::infer::PackedModel;
use pquant::serve::SpecDecoder;
use pquant::util::bench::Bencher;
use pquant::util::json::{num, obj};

fn cfg(name: &str, d_model: usize, n_layers: usize) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        variant: Variant::PQuant,
        vocab: 2048,
        d_model,
        n_layers,
        n_heads: 4,
        d_ff: 2 * d_model + d_model / 2,
        r: d_model / 8,
        n_experts: 2,
        seq_len: 256,
        alpha_init: 2.0,
        beta_init: 0.2,
    }
}

fn main() {
    let target_cfg = cfg("spec-bench-target", 256, 2);
    let mut target = PackedModel::random(&target_cfg, 7);
    let mut b = Bencher::quick();
    let prompt: Vec<u32> = (0..16).map(|i| (i * 37) % 2048).collect();
    let n_new = 48usize;
    let k = 4usize;

    // Plain greedy baseline.
    let plain_stats = b.bench("generate (plain greedy)", || {
        target.generate(&prompt, n_new).len()
    });
    let plain_tps = n_new as f64 / plain_stats.median();

    // Half-size draft: the realistic deployment shape (cheap proposals,
    // imperfect acceptance).
    let mut small_draft = PackedModel::random(&cfg("spec-bench-draft", 128, 1), 9);
    let mut dec_small = SpecDecoder::new(k);
    let small_stats = b.bench("spec decode (half-size draft)", || {
        dec_small.generate(&mut target, &mut small_draft, &prompt, n_new, None).len()
    });
    let small_tps = n_new as f64 / small_stats.median();

    // Self-draft: acceptance = 100%, the amortization ceiling.
    let mut self_draft = target.clone();
    let mut dec_self = SpecDecoder::new(k);
    let self_stats = b.bench("spec decode (self draft)  ", || {
        dec_self.generate(&mut target, &mut self_draft, &prompt, n_new, None).len()
    });
    let self_tps = n_new as f64 / self_stats.median();

    println!(
        "plain: {plain_tps:.1} tok/s | half-size draft: {small_tps:.1} tok/s \
         ({:.0}% accept, {:.2} accepted/verify) | self draft: {self_tps:.1} tok/s \
         ({:.0}% accept, {:.2} tokens/verify)",
        dec_small.stats.acceptance_rate() * 100.0,
        dec_small.stats.accepted_per_verify(),
        dec_self.stats.acceptance_rate() * 100.0,
        dec_self.stats.tokens_per_verify(),
    );
    println!(
        "spec-vs-plain tokens/sec ratio: half-size {:.2}x, self {:.2}x",
        small_tps / plain_tps,
        self_tps / plain_tps
    );

    let payload = obj(vec![
        ("plain_tokens_per_sec", num(plain_tps)),
        ("spec_tokens_per_sec", num(small_tps)),
        ("spec_self_tokens_per_sec", num(self_tps)),
        ("acceptance_rate", num(dec_small.stats.acceptance_rate())),
        ("acceptance_rate_self", num(dec_self.stats.acceptance_rate())),
        ("accepted_per_verify", num(dec_small.stats.accepted_per_verify())),
        ("tokens_per_verify", num(dec_small.stats.tokens_per_verify())),
        ("tokens_per_verify_self", num(dec_self.stats.tokens_per_verify())),
        ("spec_vs_plain_ratio", num(small_tps / plain_tps)),
        ("spec_self_vs_plain_ratio", num(self_tps / plain_tps)),
    ]);
    std::fs::create_dir_all("results/bench").ok();
    std::fs::write("results/bench/spec_decode.json", payload.to_string_pretty()).ok();
    println!("[bench] wrote results/bench/spec_decode.json");
    b.write_json("spec_decode_raw");
}
