#!/usr/bin/env bash
# Tier-1 gate + style gates for the rust crate, run from rust/:
#
#   tools/ci.sh            # build + tests + fmt + clippy
#   tools/ci.sh --tier1    # just the tier-1 gate (build + tests)
#
# Requires a rust toolchain (cargo, rustfmt, clippy) on PATH.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install the rust toolchain first" >&2
    exit 1
fi

if [[ ! -e vendor/xla/Cargo.toml ]]; then
    echo "ci.sh: rust/vendor/xla is missing — Cargo.toml expects the vendored" >&2
    echo "xla-rs (PJRT) checkout there; restore it (or repoint the path dep)" >&2
    echo "before the gate can run." >&2
    exit 1
fi

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q   (includes tests/integration_spec.rs + integration_http.rs + integration_loadgen.rs)"
cargo test -q

echo "==> tier-1: cargo bench --no-run (benches must keep compiling, incl. benches/spec_decode.rs + loadgen.rs)"
cargo bench --no-run

if [[ "${1:-}" == "--tier1" ]]; then
    echo "ci.sh: tier-1 gate passed"
    exit 0
fi

echo "==> bench lane: seeded loadgen trace → results/bench/loadgen.json"
cargo bench --bench loadgen

echo "==> bench lane: KV capacity f32 vs int8 → results/bench/kvcache.json"
cargo bench --bench kvcache

echo "==> style: cargo fmt --check"
cargo fmt --check

echo "==> lint: cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "ci.sh: all gates passed"
