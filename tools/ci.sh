#!/usr/bin/env bash
# Tier-1 gate + style gates for the rust crate, run from rust/:
#
#   tools/ci.sh            # build + tests + fmt + clippy
#   tools/ci.sh --tier1    # just the tier-1 gate (build + tests)
#
# Requires a rust toolchain (cargo, rustfmt, clippy) on PATH.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — install the rust toolchain first" >&2
    exit 1
fi

if [[ ! -e vendor/xla/Cargo.toml ]]; then
    echo "ci.sh: rust/vendor/xla is missing — Cargo.toml expects the vendored" >&2
    echo "xla-rs (PJRT) checkout there; restore it (or repoint the path dep)" >&2
    echo "before the gate can run." >&2
    exit 1
fi

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q   (includes tests/integration_spec.rs + integration_http.rs + integration_loadgen.rs)"
cargo test -q

echo "==> tier-1: PQUANT_SIMD=off cargo test -q   (scalar-oracle lane: full suite with SIMD dispatch disabled)"
PQUANT_SIMD=off cargo test -q

echo "==> tier-1: cargo bench --no-run (benches must keep compiling, incl. benches/spec_decode.rs + loadgen.rs)"
cargo bench --no-run

if [[ "${1:-}" == "--tier1" ]]; then
    echo "ci.sh: tier-1 gate passed"
    exit 0
fi

echo "==> bench lane: kernel scalar-vs-SIMD ratios → results/bench/gemm_kernels.json"
cargo bench --bench gemm_kernels

echo "==> bench lane: seeded loadgen trace → results/bench/loadgen.json"
cargo bench --bench loadgen

echo "==> bench lane: KV capacity f32 vs int8 → results/bench/kvcache.json"
cargo bench --bench kvcache

echo "==> bench lane: tracing overhead ratio → results/bench/obs.json"
cargo bench --bench obs

echo "==> obs lane: serve --trace-out + loadtest --out-jsonl + obs-check round-trip"
OBS_DIR="$(mktemp -d)"
trap 'rm -rf "$OBS_DIR"' EXIT
OBS_ADDR=127.0.0.1:8737
./target/release/repro export smoke "$OBS_DIR/smoke.pqm" --random 1
./target/release/repro serve --model "$OBS_DIR/smoke.pqm" --http "$OBS_ADDR" \
    --duration 12 --trace-out "$OBS_DIR/trace.json" &
OBS_SERVE_PID=$!
sleep 1
./target/release/repro loadtest --http "$OBS_ADDR" --requests 32 --rate 100 --seed 7 \
    --out "$OBS_DIR/load.json" --out-jsonl "$OBS_DIR/load.jsonl"
test -s "$OBS_DIR/load.jsonl"
# Live round-trip: JSON vs Prometheus metrics cross-check + /v1/trace/latest.
./target/release/repro obs-check --http "$OBS_ADDR"
wait "$OBS_SERVE_PID"
# Post-run: the --trace-out ring must be valid Chrome trace JSON with terminals.
./target/release/repro obs-check --trace "$OBS_DIR/trace.json"

echo "==> chaos lane: seeded fault injection (tests/integration_chaos.rs)"
# Each seed drives a different deterministic fault schedule through the
# failpoint registry; the invariants (one terminal event per ticket, KV
# pool drains to zero, client/server counters reconcile) must hold on all.
for seed in 11 29 47; do
    echo "  -> PQUANT_CHAOS_SEED=$seed"
    PQUANT_CHAOS_SEED=$seed cargo test -q --test integration_chaos
done

echo "==> style: cargo fmt --check"
cargo fmt --check

echo "==> lint: cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "ci.sh: all gates passed"
